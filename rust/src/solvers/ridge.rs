//! Kernel ridge regression over pairwise kernels — the paper's learning
//! algorithm (§3, §6).
//!
//! Training solves `(K + λI) a = y` with MINRES, where `K` is a
//! [`PairwiseLinOp`] (GVT, `O(nm + nq)` per iteration) or any other
//! [`LinOp`] (the explicit baseline). Regularization is either Tikhonov
//! (λ) or early stopping on a validation sample (the paper uses both,
//! Figure 3); the paper's full protocol —
//!
//! 1. split the training set into inner/validation per the setting,
//! 2. run MINRES on inner while validation AUC improves,
//! 3. refit on the full training set for the optimal iteration count —
//!
//! is [`PairwiseRidge::fit_early_stopping`].

use crate::data::{splits, PairDataset};
use crate::error::{bail, Context, Result};
use crate::eval::auc;
use crate::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use crate::gvt::vec_trick::GvtPolicy;
use crate::linalg::Mat;
use crate::solvers::cg;
use crate::solvers::linear_op::{LinOp, ShiftedOp};
use crate::solvers::minres::{minres, MinresOptions};
use crate::solvers::Solver;
use crate::sparse::PairIndex;
use std::ops::ControlFlow;
use std::sync::Arc;

/// Hyperparameters for pairwise kernel ridge regression.
#[derive(Clone, Debug)]
pub struct RidgeConfig {
    /// Tikhonov regularization λ. The paper's early-stopping experiments
    /// fix this small (1e-5) and regularize by iteration count.
    pub lambda: f64,
    /// MINRES iteration cap.
    pub max_iters: usize,
    /// MINRES relative residual tolerance.
    pub rel_tol: f64,
    /// GVT factorization policy.
    pub policy: GvtPolicy,
    /// Early stopping: stop when validation AUC hasn't improved for this
    /// many consecutive checks.
    pub patience: usize,
    /// Evaluate validation AUC every this many iterations (1 = paper).
    pub check_every: usize,
    /// Fraction of the training set held out as inner validation
    /// (the paper uses 25%).
    pub validation_fraction: f64,
}

impl Default for RidgeConfig {
    fn default() -> Self {
        Self {
            lambda: 1e-5,
            max_iters: 400,
            rel_tol: 1e-10,
            policy: GvtPolicy::Auto,
            patience: 10,
            check_every: 1,
            validation_fraction: 0.25,
        }
    }
}

/// One point of the per-iteration validation curve (Figure 3).
#[derive(Clone, Copy, Debug)]
pub struct IterPoint {
    pub iteration: usize,
    pub validation_auc: f64,
    pub rel_residual: f64,
}

/// A fitted pairwise ridge model.
pub struct RidgeModel {
    kernel: PairwiseKernel,
    d: Arc<crate::linalg::Mat>,
    t: Arc<crate::linalg::Mat>,
    train_pairs: PairIndex,
    policy: GvtPolicy,
    /// Dual coefficients `a` (one per training pair).
    pub alpha: Vec<f64>,
    /// Tikhonov λ the model was trained with — metadata for persistence
    /// and serving (`alpha` already encodes the solution). `NaN` when
    /// unknown (e.g. models loaded from a v1 artifact).
    pub lambda: f64,
    /// MINRES iterations actually run.
    pub iterations: usize,
    /// Validation curve, if trained with early stopping.
    pub history: Vec<IterPoint>,
}

impl RidgeModel {
    /// Predict scores for a sample of pairs (indices into the same drug /
    /// target domains as the training data):
    /// `p = R(test) K R(train)ᵀ a` — one GVT product, never `O(n n̄)`.
    pub fn predict(&self, pairs: &PairIndex) -> Result<Vec<f64>> {
        let op = PairwiseLinOp::new(
            self.kernel,
            self.d.clone(),
            self.t.clone(),
            pairs.clone(),
            self.train_pairs.clone(),
            self.policy,
        )
        .context("building prediction operator")?;
        Ok(op.matvec(&self.alpha))
    }

    /// The pairwise kernel the model was trained with.
    pub fn kernel(&self) -> PairwiseKernel {
        self.kernel
    }

    /// Drug kernel over the full drug domain (shared handle).
    pub fn d(&self) -> Arc<crate::linalg::Mat> {
        self.d.clone()
    }

    /// Target kernel over the full target domain (shared handle).
    pub fn t(&self) -> Arc<crate::linalg::Mat> {
        self.t.clone()
    }

    /// The GVT factorization policy the model predicts with.
    pub fn policy(&self) -> GvtPolicy {
        self.policy
    }

    /// Number of training pairs (the length of `alpha`).
    pub fn train_size(&self) -> usize {
        self.train_pairs.len()
    }

    /// The training sample the dual coefficients refer to.
    pub fn train_pairs(&self) -> &PairIndex {
        &self.train_pairs
    }

    /// Batched prediction for several models trained on the **same**
    /// sample (a λ grid, a fold's candidates): stacks the dual
    /// coefficient vectors and runs **one** multi-RHS GVT block product
    /// `P = R(test) K R(train)ᵀ [α₁ … α_B]` instead of `B` separate
    /// operator builds and mat-vecs. Column `b` holds model `b`'s
    /// predictions.
    pub fn predict_batch(models: &[RidgeModel], pairs: &PairIndex) -> Result<Mat> {
        let first = match models.first() {
            Some(m) => m,
            None => bail!("predict_batch: empty model list"),
        };
        for m in models.iter().skip(1) {
            // same_pairs (not same_view): models reloaded from disk carry
            // fresh index buffers but may still share the sample content.
            if m.kernel != first.kernel
                || !m.train_pairs.same_pairs(&first.train_pairs)
            {
                bail!(
                    "predict_batch: models must share one kernel and training sample"
                );
            }
        }
        let op = PairwiseLinOp::new(
            first.kernel,
            first.d.clone(),
            first.t.clone(),
            pairs.clone(),
            first.train_pairs.clone(),
            first.policy,
        )
        .context("building batched prediction operator")?;
        let alphas: Vec<&[f64]> = models.iter().map(|m| m.alpha.as_slice()).collect();
        Ok(op.matmat(&Mat::from_columns(&alphas)))
    }

    /// Reassemble a model from persisted parts (see
    /// [`crate::solvers::persist`]).
    pub fn from_parts(
        kernel: PairwiseKernel,
        d: Arc<crate::linalg::Mat>,
        t: Arc<crate::linalg::Mat>,
        train_pairs: PairIndex,
        policy: GvtPolicy,
        alpha: Vec<f64>,
        lambda: f64,
    ) -> Result<RidgeModel> {
        if alpha.len() != train_pairs.len() {
            bail!(
                "alpha length {} != training pairs {}",
                alpha.len(),
                train_pairs.len()
            );
        }
        Ok(RidgeModel {
            kernel,
            d,
            t,
            train_pairs,
            policy,
            alpha,
            lambda,
            iterations: 0,
            history: Vec::new(),
        })
    }
}

/// The estimator: static constructors returning [`RidgeModel`]s.
pub struct PairwiseRidge;

impl PairwiseRidge {
    /// Build the training operator for a dataset.
    fn train_op(
        data: &PairDataset,
        kernel: PairwiseKernel,
        policy: GvtPolicy,
    ) -> Result<PairwiseLinOp> {
        if !kernel.supports_heterogeneous() && !data.homogeneous {
            bail!(
                "{} requires a homogeneous dataset but '{}' is heterogeneous",
                kernel.name(),
                data.name
            );
        }
        // Spawn the runtime pool's workers up front: every solver
        // iteration over this operator runs its sweeps on the pool.
        crate::runtime::pool::warm();
        PairwiseLinOp::new(
            kernel,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            policy,
        )
    }

    /// Fit to convergence (or `max_iters`) with pure Tikhonov
    /// regularization — no early stopping.
    pub fn fit(
        data: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &RidgeConfig,
    ) -> Result<RidgeModel> {
        Self::fit_fixed_iters(data, kernel, cfg, cfg.max_iters)
    }

    /// Fit with a fixed iteration budget (step 3 of the paper's protocol).
    pub fn fit_fixed_iters(
        data: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &RidgeConfig,
        iters: usize,
    ) -> Result<RidgeModel> {
        Self::fit_exact(data, kernel, cfg, iters, Solver::Minres)
    }

    /// Fit with one of the **exact** Krylov solvers (`(K+λI)` is SPD for
    /// λ > 0, so CG applies as well as MINRES; the two agree to solver
    /// tolerance). The stochastic solver is dispatched separately —
    /// [`crate::solvers::sgd::SgdTrainer`] needs the pairwise batch
    /// structure, not just the assembled operator.
    pub fn fit_exact(
        data: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &RidgeConfig,
        iters: usize,
        solver: Solver,
    ) -> Result<RidgeModel> {
        let op = Self::train_op(data, kernel, cfg.policy)?;
        let shifted = ShiftedOp::new(&op, cfg.lambda);
        let opts = MinresOptions { max_iters: iters, rel_tol: cfg.rel_tol };
        let (alpha, iterations) = match solver {
            Solver::Minres => {
                let out = minres(&shifted, &data.y, &opts, |_, _, _| {
                    ControlFlow::Continue(())
                })?;
                (out.x, out.iterations)
            }
            Solver::Cg => {
                let out = cg::cg(
                    &shifted,
                    &data.y,
                    None,
                    &cg::CgOptions { max_iters: iters, rel_tol: cfg.rel_tol },
                    |_, _, _| ControlFlow::Continue(()),
                )?;
                (out.x, out.iterations)
            }
            Solver::Sgd => bail!(
                "fit_exact: sgd is a stochastic solver — use solvers::sgd::SgdTrainer"
            ),
            Solver::Eigen => bail!(
                "fit_exact: eigen is the direct complete-grid solver — use \
                 solvers::complete::EigenRidge"
            ),
        };
        Ok(RidgeModel {
            kernel,
            d: data.d.clone(),
            t: data.t.clone(),
            train_pairs: data.pairs.clone(),
            policy: cfg.policy,
            alpha,
            lambda: cfg.lambda,
            iterations,
            history: Vec::new(),
        })
    }

    /// CG with the **eigenbasis preconditioner** — the complete-grid
    /// eigendecomposition recycled for incomplete grids (two-step-ridge
    /// style, rust/DESIGN.md §Eigen-Shortcut). Each iteration applies
    /// `M⁻¹ = R (D ⊗ T + λI)⁻¹ Rᵀ` in the eigenbasis
    /// ([`crate::solvers::complete::EigenPrecond`]); the denser the
    /// observed sample, the closer `M⁻¹(K + λI)` is to the identity and
    /// the fewer Krylov iterations CG needs. Kronecker kernel only — the
    /// other pairwise kernels are sums of Kronecker products and do not
    /// share one eigenbasis.
    pub fn fit_eigen_precond_cg(
        data: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &RidgeConfig,
        iters: usize,
    ) -> Result<RidgeModel> {
        if kernel != PairwiseKernel::Kronecker {
            bail!(
                "--precond eigen factorizes the complete operator D ⊗ T; \
                 kernel '{}' is not a single Kronecker product",
                kernel.name()
            );
        }
        let op = Self::train_op(data, kernel, cfg.policy)?;
        let shifted = ShiftedOp::new(&op, cfg.lambda);
        let precond = crate::solvers::complete::EigenPrecond::new(
            &data.d,
            &data.t,
            data.pairs.clone(),
            cfg.lambda,
        )
        .context("building the eigen preconditioner")?;
        let out = cg::cg(
            &shifted,
            &data.y,
            Some(&precond),
            &cg::CgOptions { max_iters: iters, rel_tol: cfg.rel_tol },
            |_, _, _| ControlFlow::Continue(()),
        )?;
        Ok(RidgeModel {
            kernel,
            d: data.d.clone(),
            t: data.t.clone(),
            train_pairs: data.pairs.clone(),
            policy: cfg.policy,
            alpha: out.x,
            lambda: cfg.lambda,
            iterations: out.iterations,
            history: Vec::new(),
        })
    }

    /// Run MINRES on `inner` while tracking AUC on `validation`; returns
    /// the iteration count with the best validation AUC plus the full
    /// curve. This is steps 1–2 of the paper's protocol (and the data
    /// behind Figure 3).
    pub fn find_optimal_iters(
        inner: &PairDataset,
        validation: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &RidgeConfig,
    ) -> Result<(usize, Vec<IterPoint>)> {
        let op = Self::train_op(inner, kernel, cfg.policy)?;
        let shifted = ShiftedOp::new(&op, cfg.lambda);
        // Prediction operator: rows = validation pairs, cols = inner pairs.
        let pred_op = PairwiseLinOp::new(
            kernel,
            inner.d.clone(),
            inner.t.clone(),
            validation.pairs.clone(),
            inner.pairs.clone(),
            cfg.policy,
        )?;
        let val_labels = validation.binary_labels();

        let mut history: Vec<IterPoint> = Vec::new();
        let mut best_auc = f64::NEG_INFINITY;
        let mut best_iter = 1usize;
        let mut since_best = 0usize;

        minres(
            &shifted,
            &inner.y,
            &MinresOptions { max_iters: cfg.max_iters, rel_tol: cfg.rel_tol },
            |k, x, relres| {
                if k % cfg.check_every != 0 {
                    return ControlFlow::Continue(());
                }
                let preds = pred_op.matvec(x);
                let a = auc(&preds, &val_labels).unwrap_or(0.5);
                history.push(IterPoint {
                    iteration: k,
                    validation_auc: a,
                    rel_residual: relres,
                });
                if a > best_auc {
                    best_auc = a;
                    best_iter = k;
                    since_best = 0;
                    ControlFlow::Continue(())
                } else {
                    since_best += 1;
                    if since_best >= cfg.patience {
                        ControlFlow::Break(())
                    } else {
                        ControlFlow::Continue(())
                    }
                }
            },
        )?;
        Ok((best_iter, history))
    }

    /// The paper's full training protocol: inner/validation split per the
    /// setting, early-stopped iteration search, refit on all of `train`.
    pub fn fit_early_stopping(
        train: &PairDataset,
        setting: u8,
        kernel: PairwiseKernel,
        cfg: &RidgeConfig,
        seed: u64,
    ) -> Result<RidgeModel> {
        let inner_split =
            splits::split_setting(train, setting, cfg.validation_fraction, seed);
        let (inner, validation) = (&inner_split.train, &inner_split.test);
        if inner.is_empty() || validation.is_empty() {
            // Degenerate inner split (tiny folds): fall back to fixed iters.
            return Self::fit_fixed_iters(train, kernel, cfg, cfg.max_iters);
        }
        let (best_iter, history) =
            Self::find_optimal_iters(inner, validation, kernel, cfg)?;
        let mut model = Self::fit_fixed_iters(train, kernel, cfg, best_iter)?;
        model.history = history;
        Ok(model)
    }

    /// Baseline variant: identical protocol but the operator is an
    /// arbitrary pre-built `LinOp` (used with
    /// [`crate::gvt::explicit::ExplicitLinOp`] for the Figure 7 baseline).
    pub fn fit_with_op(
        op: &dyn LinOp,
        y: &[f64],
        cfg: &RidgeConfig,
        iters: usize,
    ) -> Result<(Vec<f64>, usize)> {
        let shifted = ShiftedOp::new(op, cfg.lambda);
        let out = minres(
            &shifted,
            y,
            &MinresOptions { max_iters: iters, rel_tol: cfg.rel_tol },
            |_, _, _| ControlFlow::Continue(()),
        )?;
        Ok((out.x, out.iterations))
    }

    /// Fit one model per λ over a **shared** training operator: the fused
    /// GVT plan, its grouping tables, and its workspace are built once and
    /// reused by every MINRES run in the sweep (only the `+λI` shift
    /// differs). The models share the training sample, so
    /// [`RidgeModel::predict_batch`] can score the whole grid with one
    /// multi-RHS product.
    pub fn fit_lambda_grid(
        data: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &RidgeConfig,
        lambdas: &[f64],
    ) -> Result<Vec<RidgeModel>> {
        let op = Self::train_op(data, kernel, cfg.policy)?;
        lambdas
            .iter()
            .map(|&lambda| {
                let shifted = ShiftedOp::new(&op, lambda);
                let out = minres(
                    &shifted,
                    &data.y,
                    &MinresOptions { max_iters: cfg.max_iters, rel_tol: cfg.rel_tol },
                    |_, _, _| ControlFlow::Continue(()),
                )?;
                Ok(RidgeModel {
                    kernel,
                    d: data.d.clone(),
                    t: data.t.clone(),
                    train_pairs: data.pairs.clone(),
                    policy: cfg.policy,
                    alpha: out.x,
                    lambda,
                    iterations: out.iterations,
                    history: Vec::new(),
                })
            })
            .collect()
    }

    /// Setting-aware k-fold cross-validation over a λ grid: per fold, fit
    /// every λ on the fold's training set ([`Self::fit_lambda_grid`], one
    /// shared operator) and score the fold's test pairs for **all** λ with
    /// one multi-RHS block product ([`RidgeModel::predict_batch`]).
    pub fn cross_validate_lambda(
        data: &PairDataset,
        setting: u8,
        kernel: PairwiseKernel,
        lambdas: &[f64],
        cfg: &RidgeConfig,
        folds: usize,
        seed: u64,
    ) -> Result<LambdaCvReport> {
        if lambdas.is_empty() {
            bail!("cross_validate_lambda: empty lambda grid");
        }
        let cv = splits::cv_splits(data, setting, folds, seed);
        let mut cells = Vec::new();
        let mut sums = vec![0.0; lambdas.len()];
        let mut counts = vec![0usize; lambdas.len()];
        for (fold, split) in cv.iter().enumerate() {
            if split.train.is_empty() || split.test.is_empty() {
                continue;
            }
            let models = Self::fit_lambda_grid(&split.train, kernel, cfg, lambdas)?;
            let preds = RidgeModel::predict_batch(&models, &split.test.pairs)?;
            let labels = split.test.binary_labels();
            for (li, model) in models.iter().enumerate() {
                let col = preds.column(li);
                let score = auc(&col, &labels).unwrap_or(0.5);
                sums[li] += score;
                counts[li] += 1;
                cells.push(LambdaCvCell {
                    lambda: lambdas[li],
                    fold,
                    auc: score,
                    iterations: model.iterations,
                });
            }
        }
        let mean_auc: Vec<(f64, f64)> = lambdas
            .iter()
            .enumerate()
            .map(|(li, &l)| (l, sums[li] / counts[li].max(1) as f64))
            .collect();
        let best_lambda = mean_auc
            .iter()
            .cloned()
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("AUC is finite"))
            .map(|(l, _)| l)
            .unwrap_or(lambdas[0]);
        Ok(LambdaCvReport { cells, mean_auc, best_lambda })
    }
}

/// One (λ, fold) cell of [`PairwiseRidge::cross_validate_lambda`].
#[derive(Clone, Debug)]
pub struct LambdaCvCell {
    pub lambda: f64,
    pub fold: usize,
    pub auc: f64,
    pub iterations: usize,
}

/// Aggregated k-fold CV result over a λ grid.
#[derive(Clone, Debug)]
pub struct LambdaCvReport {
    /// Every (λ, fold) evaluation.
    pub cells: Vec<LambdaCvCell>,
    /// `(λ, mean AUC over folds)` per grid point.
    pub mean_auc: Vec<(f64, f64)>,
    /// Grid point with the best mean AUC.
    pub best_lambda: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::explicit::explicit_matrix;
    use crate::linalg::chol::solve_regularized;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    fn toy_dataset(seed: u64, n: usize, m: usize, q: usize) -> PairDataset {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let t = Arc::new(gen::psd_kernel(&mut rng, q));
        let pairs = gen::pair_sample(&mut rng, n, m, q);
        let y = dist::normal_vec(&mut rng, n);
        PairDataset { name: "toy".into(), d, t, pairs, y, homogeneous: m == q }
    }

    #[test]
    fn converged_fit_matches_closed_form() {
        let data = toy_dataset(100, 40, 6, 7);
        let cfg = RidgeConfig {
            lambda: 0.5,
            max_iters: 2000,
            rel_tol: 1e-13,
            ..Default::default()
        };
        for kernel in [PairwiseKernel::Kronecker, PairwiseKernel::Linear, PairwiseKernel::Poly2D]
        {
            let model = PairwiseRidge::fit(&data, kernel, &cfg).unwrap();
            // Closed-form oracle from the explicit matrix.
            let k = explicit_matrix(kernel, &data.d, &data.t, &data.pairs, &data.pairs);
            let oracle = solve_regularized(&k, 0.5, &data.y).unwrap();
            for (a, o) in model.alpha.iter().zip(&oracle) {
                assert!((a - o).abs() < 1e-5, "{kernel:?}: {a} vs {o}");
            }
        }
    }

    #[test]
    fn prediction_matches_explicit_cross_matrix() {
        let data = toy_dataset(101, 50, 8, 8);
        let cfg = RidgeConfig { lambda: 1.0, max_iters: 500, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let mut rng = Xoshiro256::seed_from(102);
        let test_pairs = gen::pair_sample(&mut rng, 20, 8, 8);
        let p = model.predict(&test_pairs).unwrap();
        let kx = explicit_matrix(
            PairwiseKernel::Kronecker,
            &data.d,
            &data.t,
            &test_pairs,
            &data.pairs,
        );
        let p2 = kx.matvec(&model.alpha);
        for (a, b) in p.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn early_stopping_returns_history() {
        let data = toy_dataset(103, 120, 10, 12);
        // Binarize labels so AUC is defined.
        let mut data = data;
        data.y = data.y.iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect();
        let cfg = RidgeConfig { max_iters: 50, patience: 5, ..Default::default() };
        let model =
            PairwiseRidge::fit_early_stopping(&data, 1, PairwiseKernel::Kronecker, &cfg, 7)
                .unwrap();
        assert!(!model.history.is_empty());
        assert!(model.iterations <= 50);
        // Best iteration must be the argmax of the recorded curve.
        let best = model
            .history
            .iter()
            .max_by(|a, b| a.validation_auc.partial_cmp(&b.validation_auc).unwrap())
            .unwrap();
        assert_eq!(model.iterations, best.iteration);
    }

    #[test]
    fn homogeneous_kernel_rejected_on_heterogeneous_data() {
        let data = toy_dataset(104, 30, 5, 6);
        let r = PairwiseRidge::fit(&data, PairwiseKernel::Mlpk, &RidgeConfig::default());
        assert!(r.is_err());
    }

    /// The shared-operator λ grid must reproduce the per-λ fits exactly
    /// (same operator, same MINRES trajectory), and the batched multi-RHS
    /// prediction must match per-model prediction.
    #[test]
    fn lambda_grid_and_batch_predict_match_singles() {
        let data = toy_dataset(105, 45, 7, 6);
        let cfg = RidgeConfig { max_iters: 120, rel_tol: 1e-12, ..Default::default() };
        let lambdas = [0.1, 1.0, 10.0];
        let grid =
            PairwiseRidge::fit_lambda_grid(&data, PairwiseKernel::Kronecker, &cfg, &lambdas)
                .unwrap();
        assert_eq!(grid.len(), 3);
        let mut rng = Xoshiro256::seed_from(106);
        let test_pairs = gen::pair_sample(&mut rng, 15, 7, 6);
        let batch = RidgeModel::predict_batch(&grid, &test_pairs).unwrap();
        assert_eq!(batch.shape(), (15, 3));
        for (li, &lambda) in lambdas.iter().enumerate() {
            let single = PairwiseRidge::fit(
                &data,
                PairwiseKernel::Kronecker,
                &RidgeConfig { lambda, ..cfg.clone() },
            )
            .unwrap();
            for (a, b) in grid[li].alpha.iter().zip(&single.alpha) {
                assert!((a - b).abs() < 1e-10, "λ={lambda}: {a} vs {b}");
            }
            let preds = single.predict(&test_pairs).unwrap();
            let col = batch.column(li);
            for (a, b) in col.iter().zip(&preds) {
                assert!((a - b).abs() < 1e-8, "λ={lambda} batched vs single");
            }
        }
    }

    #[test]
    fn cg_fit_matches_minres_fit() {
        let data = toy_dataset(108, 40, 6, 7);
        let cfg = RidgeConfig {
            lambda: 1.0,
            max_iters: 800,
            rel_tol: 1e-12,
            ..Default::default()
        };
        let m1 = PairwiseRidge::fit_exact(
            &data,
            PairwiseKernel::Kronecker,
            &cfg,
            cfg.max_iters,
            Solver::Minres,
        )
        .unwrap();
        let m2 = PairwiseRidge::fit_exact(
            &data,
            PairwiseKernel::Kronecker,
            &cfg,
            cfg.max_iters,
            Solver::Cg,
        )
        .unwrap();
        for (a, b) in m1.alpha.iter().zip(&m2.alpha) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // The stochastic solver must be routed through SgdTrainer.
        assert!(PairwiseRidge::fit_exact(
            &data,
            PairwiseKernel::Kronecker,
            &cfg,
            10,
            Solver::Sgd
        )
        .is_err());
    }

    #[test]
    fn cross_validate_lambda_reports_grid() {
        let mut data = toy_dataset(107, 90, 9, 8);
        data.y = data.y.iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect();
        let cfg = RidgeConfig { max_iters: 40, ..Default::default() };
        let lambdas = [1e-3, 1.0];
        let report = PairwiseRidge::cross_validate_lambda(
            &data,
            1,
            PairwiseKernel::Kronecker,
            &lambdas,
            &cfg,
            3,
            11,
        )
        .unwrap();
        assert_eq!(report.mean_auc.len(), 2);
        assert_eq!(report.cells.len(), 6, "3 folds × 2 λ");
        assert!(lambdas.contains(&report.best_lambda));
        for (_, a) in &report.mean_auc {
            assert!((0.0..=1.0).contains(a));
        }
    }
}
