//! Step-size schedules for the stochastic trainer ([`crate::solvers::sgd`]).
//!
//! A schedule maps a step index to a multiplier in `(0, 1]` applied to the
//! trainer's auto-scaled base step `η₀ = lr / (λ̂_max + λ)`:
//!
//! * [`StepSchedule::Constant`] — `1` forever. With the base step at the
//!   block-Lipschitz bound this is randomized block coordinate descent,
//!   which converges linearly on the (strongly convex) ridge objective —
//!   the default, and what the convergence tests pin.
//! * [`StepSchedule::InvT`] — `1 / (1 + decay·t)`, the classic
//!   Robbins–Monro `O(1/t)` decay. Satisfies `Ση = ∞`, `Ση² < ∞`;
//!   preferred with momentum or large batches where the constant-step
//!   noise floor matters more than the linear rate.
//! * [`StepSchedule::Cosine`] — cosine annealing from `1` down to `floor`
//!   over the full step budget (Loshchilov & Hutter 2017 without
//!   restarts). A fixed-budget schedule: it needs the total step count,
//!   which the trainer passes in per call.

/// Step-size multiplier as a function of the step index (see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepSchedule {
    /// Constant multiplier `1`.
    Constant,
    /// `1 / (1 + decay·t)` with the given decay rate.
    InvT {
        /// Decay rate; `1e-3` is the CLI default (`--schedule invt`).
        decay: f64,
    },
    /// Cosine annealing `floor + (1 − floor)·(1 + cos(π t/T)) / 2`.
    Cosine {
        /// Multiplier the schedule anneals down to at `t = T`.
        floor: f64,
    },
}

impl StepSchedule {
    /// Multiplier for step `t` of `total` (0-based; `total` only matters
    /// for fixed-budget schedules). Always in `(0, 1]`.
    pub fn factor(&self, t: usize, total: usize) -> f64 {
        match *self {
            StepSchedule::Constant => 1.0,
            StepSchedule::InvT { decay } => 1.0 / (1.0 + decay * t as f64),
            StepSchedule::Cosine { floor } => {
                let total = total.max(1);
                let frac = (t.min(total) as f64) / total as f64;
                floor + (1.0 - floor) * 0.5 * (1.0 + (std::f64::consts::PI * frac).cos())
            }
        }
    }

    /// The canonical CLI vocabulary, aligned with [`Self::parse`] (the
    /// CLI's `opt_choice` whitelist derives from this — one source of
    /// truth).
    pub const NAMES: [&'static str; 3] = ["constant", "invt", "cosine"];

    /// Canonical name (CLI flags, bench labels).
    pub fn name(&self) -> &'static str {
        match self {
            StepSchedule::Constant => "constant",
            StepSchedule::InvT { .. } => "invt",
            StepSchedule::Cosine { .. } => "cosine",
        }
    }

    /// Parse a CLI token (exactly [`Self::NAMES`]); parameterized
    /// schedules get their defaults (`invt` → decay `1e-3`, `cosine` →
    /// floor `0.05`).
    pub fn parse(s: &str) -> Option<StepSchedule> {
        match s.to_ascii_lowercase().as_str() {
            "constant" => Some(StepSchedule::Constant),
            "invt" => Some(StepSchedule::InvT { decay: 1e-3 }),
            "cosine" => Some(StepSchedule::Cosine { floor: 0.05 }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        for t in [0, 1, 10, 1_000_000] {
            assert_eq!(StepSchedule::Constant.factor(t, 100), 1.0);
        }
    }

    #[test]
    fn invt_decays_monotonically_from_one() {
        let s = StepSchedule::InvT { decay: 0.1 };
        assert_eq!(s.factor(0, 1), 1.0);
        let mut prev = f64::INFINITY;
        for t in 0..200 {
            let f = s.factor(t, 1);
            assert!(f <= prev && f > 0.0);
            prev = f;
        }
        assert!((s.factor(10, 1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cosine_anneals_to_floor() {
        let s = StepSchedule::Cosine { floor: 0.05 };
        let total = 1000;
        assert!((s.factor(0, total) - 1.0).abs() < 1e-12);
        assert!((s.factor(total, total) - 0.05).abs() < 1e-12);
        // Past the budget it clamps at the floor rather than rebounding.
        assert!((s.factor(total * 2, total) - 0.05).abs() < 1e-12);
        let mid = s.factor(total / 2, total);
        assert!((mid - (0.05 + 0.95 * 0.5)).abs() < 1e-12);
        let mut prev = f64::INFINITY;
        for t in 0..=total {
            let f = s.factor(t, total);
            assert!(f <= prev + 1e-15 && f >= 0.05 - 1e-15);
            prev = f;
        }
    }

    #[test]
    fn parse_roundtrip() {
        for s in [
            StepSchedule::Constant,
            StepSchedule::InvT { decay: 1e-3 },
            StepSchedule::Cosine { floor: 0.05 },
        ] {
            assert_eq!(StepSchedule::parse(s.name()), Some(s));
        }
        assert_eq!(StepSchedule::parse("warmup"), None);
        // The CLI whitelist and the parser are one vocabulary.
        for name in StepSchedule::NAMES {
            let parsed = StepSchedule::parse(name).expect(name);
            assert_eq!(parsed.name(), name);
        }
    }
}
