//! Stochastic vec trick training — mini-batched SGD on the ridge dual.
//!
//! The exact solvers (MINRES/CG) pay one **full** GVT product per
//! iteration: `O(n·m_c + n·q_c)` with the stage-2 row sweep over all `n`
//! training pairs dominating for `n ≫ m, q`. Following the stochastic
//! vec trick idea (Karmitsa, Pahikkala, Airola), this module instead
//! minimizes the same objective
//!
//! ```text
//! J(α) = ½ αᵀ(K + λI)α − αᵀy        (∇J = (K + λI)α − y)
//! ```
//!
//! by sampling a mini-batch `B` of training pairs per step and updating
//! only the batch coordinates with the batch block of the gradient,
//! `α_B ← α_B − η_t · ((Kα)_B + λα_B − y_B)` — randomized block
//! coordinate descent, a.k.a. SGD under the coordinate decomposition of
//! `J`. The batch rows `(Kα)_B` are one **batch-shaped** GVT product:
//! the [`SgdTrainer`] compiles the training operator once and derives
//! each step's operator from it via [`PairwiseLinOp::with_rows`]
//! (Arc-shared kernel matrices, Hadamard squares, and training-sample
//! grouping caches — the same template path the serving
//! [`crate::serve::Predictor`] uses), threading one warm
//! [`GvtWorkspace`] through every step. A batch step costs
//! `O(n + q_c·m_c + b·m_c)` against the exact iteration's
//! `O(n + q_c·m_c + n·m_c)` — the `n ≫ b` stage-2 saving that opens
//! data-set sizes where even one full pass per iteration is too slow.
//!
//! Stability without tuning: the base step is `lr / (1.1·λ̂_max + λ)`
//! where `λ̂_max` is a power-iteration estimate of the kernel operator's
//! top eigenvalue (a handful of full GVT products, paid once per
//! trainer). Since every principal submatrix satisfies
//! `λ_max(K_BB) ≤ λ_max(K)`, the default `lr = 1` is inside the block
//! descent regime for every batch size, giving linear convergence in
//! expectation on the strongly convex objective — no learning-rate
//! search required. [`StepSchedule`]s (constant / 1-over-t / cosine),
//! heavy-ball momentum, and tail iterate averaging layer on top; see
//! rust/DESIGN.md §Stochastic-Solver for the cost model and when to
//! prefer SGD over CG.
//!
//! Epoch sampling is a shuffled pass over the training pairs
//! ([`crate::rng::dist::EpochShuffler`], Fisher–Yates under the
//! deterministic [`Xoshiro256`]), so a run is exactly reproducible from
//! its seed. A convergence monitor evaluates the full objective and
//! relative gradient norm every [`SgdConfig::check_every`] epochs (one
//! exact GVT pass via the template), stopping early on
//! [`SgdConfig::tol`] or when the objective stalls for
//! [`SgdConfig::patience`] checks.

use crate::data::PairDataset;
use crate::error::{bail, Context, Result};
use crate::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use crate::gvt::plan::GvtWorkspace;
use crate::gvt::vec_trick::GvtPolicy;
use crate::linalg::vecops::{axpy_par, dot, norm2, scale, scale_par};
use crate::rng::dist::EpochShuffler;
use crate::rng::{dist, Xoshiro256};
use crate::solvers::ridge::RidgeModel;
use crate::solvers::schedule::StepSchedule;
use crate::sparse::PairIndex;
use std::sync::{Arc, Mutex};

/// Hyperparameters of the stochastic trainer (λ is per-fit, see
/// [`SgdTrainer::fit`], so one trainer serves a whole λ grid).
#[derive(Clone, Debug)]
pub struct SgdConfig {
    /// Mini-batch size `b` (clamped to `[1, n]` at fit time).
    pub batch_size: usize,
    /// Maximum shuffled passes over the training pairs.
    pub epochs: usize,
    /// Step-size multiplier on the auto-scaled base step
    /// `1 / (1.1·λ̂_max + λ)`. `1.0` (default) is always stable; values
    /// above ~2 leave the block descent regime.
    pub lr: f64,
    /// Heavy-ball momentum μ (`0` disables; disabling keeps the
    /// per-step cost at `O(b)` vector work — momentum's velocity update
    /// is `O(n)` per step).
    pub momentum: f64,
    /// Tail iterate averaging: return the average of the iterates seen
    /// in the second half of the epoch budget instead of the last
    /// iterate. Lowers the noise floor of decayed-step runs; off by
    /// default because with the constant safe step the last iterate
    /// converges linearly and averaging only lags it.
    pub averaging: bool,
    /// Step-size schedule (multiplies the base step).
    pub schedule: StepSchedule,
    /// GVT factorization policy; `Auto` is resolved once on the
    /// training-shaped plan and pinned for every batch, so the step
    /// arithmetic does not depend on the batch size.
    pub policy: GvtPolicy,
    /// Convergence monitor: stop when `‖(K+λI)α − y‖ / ‖y‖ ≤ tol`.
    pub tol: f64,
    /// Run the (full-pass) monitor every this many epochs.
    pub check_every: usize,
    /// Stop when the monitored objective has not improved for this many
    /// consecutive checks.
    pub patience: usize,
    /// Power-iteration count for the λ̂_max estimate (paid once per
    /// trainer; each iteration is one full GVT product).
    pub power_iters: usize,
}

impl Default for SgdConfig {
    fn default() -> Self {
        Self {
            batch_size: 512,
            epochs: 200,
            lr: 1.0,
            momentum: 0.0,
            averaging: false,
            schedule: StepSchedule::Constant,
            policy: GvtPolicy::Auto,
            tol: 1e-6,
            check_every: 1,
            patience: 20,
            power_iters: 24,
        }
    }
}

/// One convergence-monitor checkpoint (a full-pass evaluation).
#[derive(Clone, Copy, Debug)]
pub struct SgdCheckpoint {
    /// Epochs completed when the check ran.
    pub epoch: usize,
    /// Ridge dual objective `½αᵀ(K+λI)α − αᵀy` of the candidate iterate.
    pub objective: f64,
    /// Relative gradient norm `‖(K+λI)α − y‖ / ‖y‖`.
    pub rel_grad: f64,
}

/// Result of one [`SgdTrainer::fit`] run.
#[derive(Clone, Debug)]
pub struct SgdRun {
    /// Final dual coefficients (the tail average when
    /// [`SgdConfig::averaging`] is on and the tail was reached).
    pub alpha: Vec<f64>,
    /// Epochs completed.
    pub epochs: usize,
    /// Mini-batch steps taken.
    pub steps: usize,
    /// Whether the gradient-norm tolerance was reached.
    pub converged: bool,
    /// Final relative gradient norm (from the last monitor pass).
    pub rel_grad: f64,
    /// Final objective value (from the last monitor pass).
    pub objective: f64,
    /// The monitor trajectory (one entry per check).
    pub history: Vec<SgdCheckpoint>,
    /// The auto-scaled base step the run used (before the schedule).
    pub base_step: f64,
}

/// Compiled stochastic trainer for one (dataset, kernel): the training
/// operator template, its pinned factorization, the warm workspace, and
/// the power-iteration λ̂_max estimate are all built **once** and shared
/// by every [`Self::fit`] call (a λ grid re-uses all of it — only the
/// diagonal shift differs). See module docs.
pub struct SgdTrainer {
    kernel: PairwiseKernel,
    d: Arc<crate::linalg::Mat>,
    t: Arc<crate::linalg::Mat>,
    pairs: PairIndex,
    y: Vec<f64>,
    /// Training-shaped operator (`rows == cols == train`): monitor
    /// passes and the `with_rows` template for batch operators.
    template: PairwiseLinOp,
    /// Concrete (never `Auto`) factorization every step executes.
    policy: GvtPolicy,
    /// Power-iteration estimate of `λ_max(K)` over the training sample.
    lmax: f64,
    cfg: SgdConfig,
    /// Warm workspace carried across the per-batch operators (the
    /// template keeps its own, staying warm at the full shape for
    /// monitor passes).
    ws: Mutex<GvtWorkspace>,
}

impl SgdTrainer {
    /// Compile a trainer for `data` under `kernel`. Builds the training
    /// operator, pins `Auto` to the concrete factorization the
    /// training-shaped plan resolves, pre-warms the training sample's
    /// CSR grouping caches (shared by every batch operator), and runs
    /// the power iteration for the step-size bound.
    pub fn new(data: &PairDataset, kernel: PairwiseKernel, cfg: SgdConfig) -> Result<SgdTrainer> {
        if !kernel.supports_heterogeneous() && !data.homogeneous {
            bail!(
                "{} requires a homogeneous dataset but '{}' is heterogeneous",
                kernel.name(),
                data.name
            );
        }
        if data.is_empty() {
            bail!("sgd: empty training set");
        }
        // Spawn the runtime pool's workers before the first batch product
        // so step-time measurements never include thread creation.
        crate::runtime::pool::warm();
        let train = data.pairs.clone();
        // Build the grouping caches on the canonical sample before the
        // first operator build so every per-batch operator inherits the
        // built `Arc`s (same pre-warm as the serving predictor).
        train.by_drug();
        train.by_target();
        let template = PairwiseLinOp::new(
            kernel,
            data.d.clone(),
            data.t.clone(),
            train.clone(),
            train.clone(),
            cfg.policy,
        )
        .context("compiling the sgd training operator")?;
        let policy = template.resolved_mode();
        let template = if policy == template.policy() {
            template
        } else {
            template
                .with_policy(policy)
                .context("re-pinning the sgd training operator")?
        };
        let lmax = estimate_lambda_max(&template, cfg.power_iters.max(4));
        Ok(SgdTrainer {
            kernel,
            d: data.d.clone(),
            t: data.t.clone(),
            pairs: train,
            y: data.y.clone(),
            template,
            policy,
            lmax,
            cfg,
            ws: Mutex::new(GvtWorkspace::new()),
        })
    }

    /// The power-iteration estimate of the kernel operator's top
    /// eigenvalue (before the 10% safety margin the step applies).
    pub fn lambda_max(&self) -> f64 {
        self.lmax
    }

    /// The pinned concrete GVT factorization (see [`SgdConfig::policy`]).
    pub fn policy(&self) -> GvtPolicy {
        self.policy
    }

    /// Run mini-batched SGD for Tikhonov parameter `lambda`. The run is
    /// exactly reproducible from `seed` (epoch shuffles are the only
    /// randomness).
    pub fn fit(&self, lambda: f64, seed: u64) -> Result<SgdRun> {
        if !(lambda >= 0.0) {
            bail!("sgd: lambda must be non-negative, got {lambda}");
        }
        let n = self.pairs.len();
        let ynorm = norm2(&self.y);
        if ynorm == 0.0 {
            return Ok(SgdRun {
                alpha: vec![0.0; n],
                epochs: 0,
                steps: 0,
                converged: true,
                rel_grad: 0.0,
                objective: 0.0,
                history: Vec::new(),
                base_step: 0.0,
            });
        }
        let b = self.cfg.batch_size.clamp(1, n);
        let steps_per_epoch = (n + b - 1) / b;
        let total_steps = self.cfg.epochs * steps_per_epoch;
        let base_step = self.cfg.lr / (1.1 * self.lmax + lambda).max(f64::MIN_POSITIVE);
        // Tail averaging starts at the midpoint of the epoch budget.
        let avg_from_epoch = self.cfg.epochs / 2;

        let mut rng = Xoshiro256::seed_from(seed);
        let mut shuffler = EpochShuffler::new(n);
        let mut alpha = vec![0.0; n];
        let mut velocity = if self.cfg.momentum > 0.0 { Some(vec![0.0; n]) } else { None };
        let mut avg = if self.cfg.averaging { Some((vec![0.0; n], 0usize)) } else { None };
        let mut kb: Vec<f64> = Vec::with_capacity(b);
        let mut candidate = vec![0.0; n];
        let mut grad = vec![0.0; n];
        let mut history = Vec::new();

        let mut steps = 0usize;
        let mut epochs = 0usize;
        let mut converged = false;
        let mut rel_grad = 1.0;
        let mut objective = 0.0;
        let mut best_obj = f64::INFINITY;
        let mut stalled = 0usize;

        // lint: alloc_free — no ad-hoc allocation idioms inside the step
        // loop: all O(n) state is sized above, and the O(b) per-step
        // operator derivation is confined to `subset`/`with_rows` (their
        // setup cost is by design; see tests/alloc_free.rs for the
        // measured guarantee on the shared GVT product).
        'train: for epoch in 0..self.cfg.epochs {
            let order = shuffler.shuffle(&mut rng);
            for chunk in order.chunks(b) {
                // Batch-shaped operator from the template: Arc-shared
                // matrices/squares, pre-warmed grouping caches; only the
                // O(b) row sample and its plan tables are fresh.
                let batch = self.pairs.subset(chunk);
                let op = self.template.with_rows(batch)?;
                op.install_workspace(std::mem::take(
                    &mut *self.ws.lock().expect("sgd workspace poisoned"),
                ));
                kb.clear();
                kb.resize(chunk.len(), 0.0);
                op.matvec_into(&alpha, &mut kb);
                *self.ws.lock().expect("sgd workspace poisoned") = op.take_workspace();

                let step = base_step * self.cfg.schedule.factor(steps, total_steps);
                match velocity.as_mut() {
                    None => {
                        // Pure block step: O(b) beyond the GVT product.
                        for (j, &i) in chunk.iter().enumerate() {
                            let g = kb[j] + lambda * alpha[i] - self.y[i];
                            alpha[i] -= step * g;
                        }
                    }
                    Some(v) => {
                        // Heavy ball: v ← μv + ĝ; α ← α − η_t v. The
                        // O(n) vector work rides the pool at large n.
                        scale_par(v, self.cfg.momentum);
                        for (j, &i) in chunk.iter().enumerate() {
                            v[i] += kb[j] + lambda * alpha[i] - self.y[i];
                        }
                        axpy_par(-step, v, &mut alpha);
                    }
                }
                if let Some((sum, count)) = avg.as_mut() {
                    if epoch >= avg_from_epoch {
                        axpy_par(1.0, &alpha, sum);
                        *count += 1;
                    }
                }
                steps += 1;
            }
            epochs = epoch + 1;

            let last_epoch = epochs == self.cfg.epochs;
            if epochs % self.cfg.check_every.max(1) != 0 && !last_epoch {
                continue;
            }
            // Full-pass monitor on the candidate iterate (the tail
            // average once it has samples, else the current iterate).
            let cand: &[f64] = match &avg {
                Some((sum, count)) if *count > 0 => {
                    let inv = 1.0 / *count as f64;
                    for (c, s) in candidate.iter_mut().zip(sum) {
                        *c = s * inv;
                    }
                    &candidate
                }
                _ => &alpha,
            };
            self.template.matvec_into(cand, &mut grad);
            for ((g, &a), &yi) in grad.iter_mut().zip(cand).zip(&self.y) {
                *g += lambda * a - yi;
            }
            // With g = (K+λI)α − y: αᵀ(K+λI)α = αᵀ(g + y), so
            // J = ½αᵀ(K+λI)α − αᵀy = ½αᵀ(g + y) − αᵀy = ½·αᵀ(g − y).
            objective = 0.5 * (dot(cand, &grad) - dot(cand, &self.y));
            rel_grad = norm2(&grad) / ynorm;
            history.push(SgdCheckpoint { epoch: epochs, objective, rel_grad });
            // Values only — wall-time is stamped by the obs layer, never here.
            crate::obs::iter::record(epochs, rel_grad);
            if !objective.is_finite() || !rel_grad.is_finite() {
                // Divergence (lr past the stability bound): fail loudly
                // instead of burning the epoch budget and returning NaNs.
                bail!(
                    "sgd diverged at epoch {epochs} (objective {objective}, \
                     rel grad {rel_grad}) — reduce the step multiplier (lr {})",
                    self.cfg.lr
                );
            }
            if rel_grad <= self.cfg.tol {
                converged = true;
                break 'train;
            }
            let improved = !best_obj.is_finite()
                || objective < best_obj - 1e-12 * best_obj.abs().max(1.0);
            if improved {
                best_obj = objective;
                stalled = 0;
            } else {
                stalled += 1;
                if stalled >= self.cfg.patience.max(1) {
                    break 'train;
                }
            }
        }

        let alpha = match avg {
            Some((sum, count)) if count > 0 => {
                let inv = 1.0 / count as f64;
                sum.iter().map(|s| s * inv).collect()
            }
            _ => alpha,
        };
        Ok(SgdRun {
            alpha,
            epochs,
            steps,
            converged,
            rel_grad,
            objective,
            history,
            base_step,
        })
    }

    /// [`Self::fit`] wrapped into a [`RidgeModel`] (same artifact shape
    /// as the exact solvers: `gvt-rls predict`/`serve` work unchanged).
    pub fn fit_model(&self, lambda: f64, seed: u64) -> Result<RidgeModel> {
        let run = self.fit(lambda, seed)?;
        let mut model = RidgeModel::from_parts(
            self.kernel,
            self.d.clone(),
            self.t.clone(),
            self.pairs.clone(),
            self.policy,
            run.alpha,
            lambda,
        )?;
        model.iterations = run.steps;
        Ok(model)
    }
}

/// One-shot convenience: compile a trainer and fit once.
pub fn fit_sgd(
    data: &PairDataset,
    kernel: PairwiseKernel,
    lambda: f64,
    cfg: &SgdConfig,
    seed: u64,
) -> Result<RidgeModel> {
    SgdTrainer::new(data, kernel, cfg.clone())?.fit_model(lambda, seed)
}

/// Power-iteration estimate of the training operator's top eigenvalue
/// (`K` is symmetric PSD on the training sample, so the Rayleigh
/// quotient of the iterate converges to `λ_max` from below). Seeded with
/// a fixed constant — the estimate is part of the deterministic trainer
/// state, independent of the per-fit seed.
fn estimate_lambda_max(op: &PairwiseLinOp, iters: usize) -> f64 {
    let n = op.rows().len();
    let mut rng = Xoshiro256::seed_from(0x9e37_79b9_7f4a_7c15);
    let mut v = dist::normal_vec(&mut rng, n);
    let mut kv = vec![0.0; n];
    let mut lmax = 0.0;
    for _ in 0..iters {
        let vnorm = norm2(&v);
        if vnorm == 0.0 || !vnorm.is_finite() {
            break;
        }
        scale(&mut v, 1.0 / vnorm);
        op.matvec_into(&v, &mut kv);
        lmax = dot(&v, &kv);
        std::mem::swap(&mut v, &mut kv);
    }
    lmax.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::explicit::explicit_matrix;
    use crate::linalg::chol::solve_regularized;
    use crate::rng::dist as rdist;
    use crate::testing::gen;

    fn toy(seed: u64, n: usize, m: usize, q: usize) -> PairDataset {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let t = Arc::new(gen::psd_kernel(&mut rng, q));
        let pairs = gen::pair_sample(&mut rng, n, m, q);
        let y = rdist::normal_vec(&mut rng, n);
        PairDataset { name: "sgd-toy".into(), d, t, pairs, y, homogeneous: m == q }
    }

    #[test]
    fn lambda_max_estimate_matches_explicit_matrix() {
        let data = toy(300, 35, 6, 7);
        let trainer = SgdTrainer::new(&data, PairwiseKernel::Kronecker, SgdConfig::default())
            .unwrap();
        // Oracle: many power iterations on the explicit matrix.
        let k = explicit_matrix(
            PairwiseKernel::Kronecker,
            &data.d,
            &data.t,
            &data.pairs,
            &data.pairs,
        );
        let mut v = vec![1.0; 35];
        let mut oracle = 0.0;
        for _ in 0..300 {
            let kv = k.matvec(&v);
            let nrm = norm2(&kv);
            oracle = dot(&v, &kv) / dot(&v, &v);
            v = kv.iter().map(|x| x / nrm).collect();
        }
        let est = trainer.lambda_max();
        assert!(est > 0.0);
        assert!(
            (est - oracle).abs() < 0.2 * oracle,
            "power-iteration estimate {est} vs oracle {oracle}"
        );
    }

    #[test]
    fn converges_to_closed_form_on_small_problem() {
        let data = toy(301, 40, 6, 7);
        let cfg = SgdConfig {
            batch_size: 8,
            epochs: 20_000,
            tol: 1e-8,
            check_every: 25,
            patience: 200,
            ..Default::default()
        };
        let lambda = 2.0;
        let trainer = SgdTrainer::new(&data, PairwiseKernel::Kronecker, cfg).unwrap();
        let run = trainer.fit(lambda, 11).unwrap();
        assert!(run.converged, "rel_grad {} after {} epochs", run.rel_grad, run.epochs);
        let k = explicit_matrix(
            PairwiseKernel::Kronecker,
            &data.d,
            &data.t,
            &data.pairs,
            &data.pairs,
        );
        let oracle = solve_regularized(&k, lambda, &data.y).unwrap();
        for (a, o) in run.alpha.iter().zip(&oracle) {
            assert!((a - o).abs() < 1e-5, "{a} vs {o}");
        }
        // Monitor trajectory is recorded and the objective decreases
        // from first to last check.
        assert!(run.history.len() >= 2);
        assert!(run.history.last().unwrap().objective < run.history[0].objective);
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        let data = toy(302, 36, 6, 6);
        let cfg = SgdConfig {
            batch_size: 8,
            epochs: 7,
            tol: 0.0,
            ..Default::default()
        };
        let trainer = SgdTrainer::new(&data, PairwiseKernel::Linear, cfg).unwrap();
        let a = trainer.fit(0.5, 42).unwrap().alpha;
        let b = trainer.fit(0.5, 42).unwrap().alpha;
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "same seed must reproduce the trajectory bit-for-bit"
        );
        let c = trainer.fit(0.5, 43).unwrap().alpha;
        assert_ne!(a, c, "different seeds shuffle differently");
    }

    #[test]
    fn momentum_averaging_and_schedules_still_converge() {
        let data = toy(303, 32, 5, 5);
        let lambda = 2.0;
        let variants = [
            SgdConfig {
                momentum: 0.5,
                schedule: StepSchedule::Constant,
                ..loose()
            },
            SgdConfig {
                schedule: StepSchedule::InvT { decay: 1e-4 },
                ..loose()
            },
            SgdConfig {
                schedule: StepSchedule::Cosine { floor: 0.2 },
                averaging: true,
                ..loose()
            },
        ];
        fn loose() -> SgdConfig {
            SgdConfig {
                batch_size: 8,
                epochs: 8_000,
                tol: 1e-3,
                check_every: 25,
                patience: 100,
                ..Default::default()
            }
        }
        for cfg in variants {
            let label = format!("schedule={} momentum={}", cfg.schedule.name(), cfg.momentum);
            let trainer = SgdTrainer::new(&data, PairwiseKernel::Kronecker, cfg).unwrap();
            let run = trainer.fit(lambda, 5).unwrap();
            assert!(
                run.rel_grad < 0.05,
                "{label}: rel_grad {} after {} epochs",
                run.rel_grad,
                run.epochs
            );
        }
    }

    #[test]
    fn rejects_homogeneous_kernel_on_heterogeneous_data() {
        let data = toy(304, 20, 4, 5);
        assert!(SgdTrainer::new(&data, PairwiseKernel::Mlpk, SgdConfig::default()).is_err());
    }

    #[test]
    fn divergent_lr_fails_loudly() {
        let data = toy(306, 30, 5, 5);
        // lr far past the stability bound; patience high so the
        // non-finite monitor check (not the stall check) fires.
        let cfg = SgdConfig {
            batch_size: 30,
            epochs: 500,
            lr: 100.0,
            check_every: 1,
            patience: 10_000,
            ..Default::default()
        };
        let trainer = SgdTrainer::new(&data, PairwiseKernel::Kronecker, cfg).unwrap();
        let err = trainer.fit(1e-3, 1);
        assert!(err.is_err(), "divergence must error, not return NaN α");
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("diverged"), "{msg}");
    }

    #[test]
    fn zero_rhs_returns_zero_immediately() {
        let mut data = toy(305, 15, 4, 4);
        data.y = vec![0.0; 15];
        let trainer = SgdTrainer::new(&data, PairwiseKernel::Kronecker, SgdConfig::default())
            .unwrap();
        let run = trainer.fit(1.0, 1).unwrap();
        assert!(run.converged);
        assert_eq!(run.steps, 0);
        assert!(run.alpha.iter().all(|&a| a == 0.0));
    }
}
