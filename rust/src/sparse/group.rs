//! CSR-style group-by: for each key `k` in `0..domain`, the list of row
//! positions whose key equals `k`. Built by counting sort in `O(n + domain)`.
//!
//! GVT stage 1 iterates pairs grouped by drug so that the accumulation into
//! the intermediate matrix `S` walks each drug's column contiguously.

/// Grouping of `n` rows by a `u32` key with known domain size.
#[derive(Clone, Debug)]
pub struct GroupBy {
    /// `offsets[k]..offsets[k+1]` indexes `rows` for key `k`.
    offsets: Vec<u32>,
    /// Row positions, grouped by key, stable within a group.
    rows: Vec<u32>,
}

impl GroupBy {
    /// Build the grouping. `keys[i] < domain` must hold for all `i`.
    pub fn build(keys: &[u32], domain: usize) -> Self {
        let n = keys.len();
        let mut counts = vec![0u32; domain + 1];
        for &k in keys {
            counts[k as usize + 1] += 1;
        }
        for k in 0..domain {
            counts[k + 1] += counts[k];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut rows = vec![0u32; n];
        for (i, &k) in keys.iter().enumerate() {
            let c = &mut cursor[k as usize];
            rows[*c as usize] = i as u32;
            *c += 1;
        }
        Self { offsets, rows }
    }

    /// Number of distinct keys in the domain.
    #[inline]
    pub fn domain(&self) -> usize {
        self.offsets.len() - 1
    }

    /// The CSR offset array: `offsets()[k]..offsets()[k+1]` indexes
    /// [`Self::positions`] for key `k`. Length `domain() + 1`.
    #[inline]
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// All row positions, grouped by key (the CSR payload). The GVT
    /// stage-1 kernels stream this directly instead of calling
    /// [`Self::group`] per key.
    #[inline]
    pub fn positions(&self) -> &[u32] {
        &self.rows
    }

    /// Row positions whose key is `k`.
    #[inline]
    pub fn group(&self, k: usize) -> &[u32] {
        let lo = self.offsets[k] as usize;
        let hi = self.offsets[k + 1] as usize;
        &self.rows[lo..hi]
    }

    /// Number of rows with key `k`.
    #[inline]
    pub fn count(&self, k: usize) -> usize {
        (self.offsets[k + 1] - self.offsets[k]) as usize
    }

    /// Iterate `(key, rows)` over non-empty groups.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &[u32])> + '_ {
        (0..self.domain()).filter_map(move |k| {
            let g = self.group(k);
            (!g.is_empty()).then_some((k, g))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_are_stable_and_complete() {
        let keys = vec![2u32, 0, 2, 1, 0, 2];
        let g = GroupBy::build(&keys, 4);
        assert_eq!(g.group(0), &[1, 4]);
        assert_eq!(g.group(1), &[3]);
        assert_eq!(g.group(2), &[0, 2, 5]);
        assert_eq!(g.group(3), &[] as &[u32]);
        let total: usize = (0..4).map(|k| g.count(k)).sum();
        assert_eq!(total, keys.len());
    }

    #[test]
    fn iter_skips_empty() {
        let keys = vec![1u32, 1, 1];
        let g = GroupBy::build(&keys, 3);
        let got: Vec<usize> = g.iter().map(|(k, _)| k).collect();
        assert_eq!(got, vec![1]);
    }

    #[test]
    fn empty_input() {
        let g = GroupBy::build(&[], 5);
        assert_eq!(g.domain(), 5);
        for k in 0..5 {
            assert_eq!(g.count(k), 0);
        }
    }
}
