//! Oriented incidence operator `M ∈ R^{D×n}` of §4.6:
//!
//! ```text
//! M[d, i] = +1 if d_i  = d
//!           -1 if d'_i = d
//!            0 otherwise
//! ```
//!
//! The ranking kernel matrix is `MᵀDM`, so its mat-vec is
//! `Mᵀ (D (M a))` — `O(m² + n)` — the Pahikkala et al. (2009) shortcut the
//! paper cites. Kept alongside the GVT formulation (`(I−P)(D⊗1)(I−P)` with
//! two Ones-fast-path terms) so benches can compare the two.

use crate::linalg::Mat;
use crate::sparse::PairIndex;

/// Incidence operator over a homogeneous pair sample `(d_i, d'_i)`.
#[derive(Clone, Debug)]
pub struct Incidence {
    /// Positive endpoint per pair (`d_i`).
    pos: Vec<u32>,
    /// Negative endpoint per pair (`d'_i`).
    neg: Vec<u32>,
    /// Domain size `m`.
    m: usize,
}

impl Incidence {
    /// Build from a homogeneous pair sample (drug slot = `d`, target slot =
    /// `d'`). Requires `pairs.m() == pairs.q()`.
    pub fn from_pairs(pairs: &PairIndex) -> Self {
        assert_eq!(
            pairs.m(),
            pairs.q(),
            "incidence operator needs a homogeneous domain"
        );
        Self { pos: pairs.drugs().to_vec(), neg: pairs.targets().to_vec(), m: pairs.m() }
    }

    /// Number of pairs `n`.
    pub fn len(&self) -> usize {
        self.pos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pos.is_empty()
    }

    /// `y = M a` : scatter each pair weight onto its endpoints. `O(n)`.
    pub fn apply(&self, a: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), self.len());
        let mut y = vec![0.0; self.m];
        for i in 0..a.len() {
            y[self.pos[i] as usize] += a[i];
            y[self.neg[i] as usize] -= a[i];
        }
        y
    }

    /// `p = Mᵀ v` : gather endpoint values back to pairs. `O(n)`.
    pub fn apply_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.m);
        (0..self.len())
            .map(|i| v[self.pos[i] as usize] - v[self.neg[i] as usize])
            .collect()
    }

    /// Full ranking-kernel mat-vec `p = Mᵀ D (M a)` in `O(m² + n)`.
    pub fn ranking_matvec(&self, d: &Mat, a: &[f64]) -> Vec<f64> {
        assert_eq!(d.rows(), self.m);
        assert_eq!(d.cols(), self.m);
        let v = self.apply(a);
        let w = d.matvec(&v);
        self.apply_t(&w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranking_matvec_matches_explicit() {
        // Explicit ranking kernel: k((d,d'),(e,e')) =
        //   D[d,e] - D[d,e'] - D[d',e] + D[d',e'].
        let m = 4;
        let d = Mat::from_fn(m, m, |i, j| ((i * 7 + j * 3) % 5) as f64 + if i == j { 2.0 } else { 0.0 });
        // Symmetrize.
        let d = {
            let t = d.transpose();
            let mut s = d.clone();
            s.axpy(1.0, &t);
            s.scale(0.5);
            s
        };
        let pairs = PairIndex::new(vec![0, 1, 2, 3, 0], vec![1, 2, 3, 0, 2], m, m);
        let inc = Incidence::from_pairs(&pairs);
        let a = vec![0.3, -1.0, 2.0, 0.5, -0.25];
        let p = inc.ranking_matvec(&d, &a);
        // Naive O(n²).
        let n = pairs.len();
        for i in 0..n {
            let (di, dpi) = (pairs.drug(i), pairs.target(i));
            let mut expect = 0.0;
            for j in 0..n {
                let (dj, dpj) = (pairs.drug(j), pairs.target(j));
                let k = d[(di, dj)] - d[(di, dpj)] - d[(dpi, dj)] + d[(dpi, dpj)];
                expect += k * a[j];
            }
            assert!((p[i] - expect).abs() < 1e-10, "row {i}: {} vs {expect}", p[i]);
        }
    }

    #[test]
    fn apply_and_apply_t_are_adjoint() {
        use crate::rng::{dist, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(8);
        let pairs = PairIndex::new(vec![0, 2, 1, 3], vec![1, 0, 3, 2], 4, 4);
        let inc = Incidence::from_pairs(&pairs);
        let a = dist::normal_vec(&mut rng, 4);
        let v = dist::normal_vec(&mut rng, 4);
        // <Ma, v> == <a, Mᵀv>
        let lhs: f64 = inc.apply(&a).iter().zip(&v).map(|(x, y)| x * y).sum();
        let rhs: f64 = a.iter().zip(inc.apply_t(&v)).map(|(x, y)| x * y).sum();
        assert!((lhs - rhs).abs() < 1e-12);
    }
}
