//! Sparse index structures for pairwise samples.
//!
//! The observed data is a list of `n` (drug, target) index pairs over `m`
//! unique drugs and `q` unique targets (the paper's sampling operator
//! `R(d, t)`). GVT's inner loops need the pairs grouped by drug or by
//! target; [`GroupBy`] is that CSR-style view. [`Incidence`] is the oriented
//! incidence operator `M` of §4.6 used by the ranking-kernel shortcut.

mod group;
mod incidence;
mod pair_index;

pub use group::GroupBy;
pub use incidence::Incidence;
pub use pair_index::PairIndex;
