//! The sampling operator `R(d, t)`: a sequence of (drug, target) index
//! pairs. Rows of any pairwise kernel matrix are indexed by such a sample.

use crate::sparse::GroupBy;
use std::sync::OnceLock;

/// A sample of `n` (drug, target) pairs over index domains
/// `0..m` (drugs) and `0..q` (targets).
///
/// This is the concrete form of the paper's `R(d, t) ∈ R^{n×(D×T)}`:
/// `drugs[i]` and `targets[i]` give the nonzero column of row `i`.
///
/// The commutation/unification operators of Definition 1 act on samples by
/// index plumbing only (`R(d,t)P = R(t,d)`, `R(d,t)Q = R(d,d)`), exposed
/// here as [`PairIndex::swapped`] and [`PairIndex::dupe_drugs`] /
/// [`PairIndex::dupe_targets`].
#[derive(Clone, Debug)]
pub struct PairIndex {
    drugs: Vec<u32>,
    targets: Vec<u32>,
    m: usize,
    q: usize,
    by_drug: OnceLock<GroupBy>,
    by_target: OnceLock<GroupBy>,
}

impl PairIndex {
    /// Build from parallel index vectors. Panics if any index is out of
    /// range — the coordinator validates data at the boundary.
    pub fn new(drugs: Vec<u32>, targets: Vec<u32>, m: usize, q: usize) -> Self {
        assert_eq!(drugs.len(), targets.len(), "drug/target length mismatch");
        assert!(
            drugs.iter().all(|&d| (d as usize) < m),
            "drug index out of range (m={m})"
        );
        assert!(
            targets.iter().all(|&t| (t as usize) < q),
            "target index out of range (q={q})"
        );
        Self { drugs, targets, m, q, by_drug: OnceLock::new(), by_target: OnceLock::new() }
    }

    /// The complete sample: every (drug, target) combination, row-major in
    /// drugs (i.e. `vec` ordering of an `m×q` label matrix by rows).
    pub fn complete(m: usize, q: usize) -> Self {
        let n = m * q;
        let mut drugs = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        for d in 0..m as u32 {
            for t in 0..q as u32 {
                drugs.push(d);
                targets.push(t);
            }
        }
        Self::new(drugs, targets, m, q)
    }

    /// Number of pairs `n`.
    #[inline]
    pub fn len(&self) -> usize {
        self.drugs.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.drugs.is_empty()
    }

    /// Number of drug indices in the domain (`m`).
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Number of target indices in the domain (`q`).
    #[inline]
    pub fn q(&self) -> usize {
        self.q
    }

    /// Drug index of pair `i`.
    #[inline]
    pub fn drug(&self, i: usize) -> usize {
        self.drugs[i] as usize
    }

    /// Target index of pair `i`.
    #[inline]
    pub fn target(&self, i: usize) -> usize {
        self.targets[i] as usize
    }

    /// Borrow the raw drug index vector.
    #[inline]
    pub fn drugs(&self) -> &[u32] {
        &self.drugs
    }

    /// Borrow the raw target index vector.
    #[inline]
    pub fn targets(&self) -> &[u32] {
        &self.targets
    }

    /// `R(d,t) P = R(t,d)` — swap the roles of drugs and targets.
    /// Only meaningful when composed against operators over the matching
    /// domains (homogeneous case, or a `T ⊗ D` term).
    pub fn swapped(&self) -> PairIndex {
        PairIndex::new(self.targets.clone(), self.drugs.clone(), self.q, self.m)
    }

    /// `R(d,t) Q = R(d,d)` — duplicate the drug index into both slots.
    pub fn dupe_drugs(&self) -> PairIndex {
        PairIndex::new(self.drugs.clone(), self.drugs.clone(), self.m, self.m)
    }

    /// `R(d,t) P Q = R(t,t)` — duplicate the target index into both slots.
    pub fn dupe_targets(&self) -> PairIndex {
        PairIndex::new(self.targets.clone(), self.targets.clone(), self.q, self.q)
    }

    /// Take the sub-sample at `rows` (for train/test splits).
    pub fn subset(&self, rows: &[usize]) -> PairIndex {
        let drugs = rows.iter().map(|&i| self.drugs[i]).collect();
        let targets = rows.iter().map(|&i| self.targets[i]).collect();
        PairIndex::new(drugs, targets, self.m, self.q)
    }

    /// Number of *distinct* drugs appearing in this sample (≤ m).
    pub fn distinct_drugs(&self) -> usize {
        let mut seen = vec![false; self.m];
        let mut c = 0;
        for &d in &self.drugs {
            if !seen[d as usize] {
                seen[d as usize] = true;
                c += 1;
            }
        }
        c
    }

    /// Number of *distinct* targets appearing in this sample (≤ q).
    pub fn distinct_targets(&self) -> usize {
        let mut seen = vec![false; self.q];
        let mut c = 0;
        for &t in &self.targets {
            if !seen[t as usize] {
                seen[t as usize] = true;
                c += 1;
            }
        }
        c
    }

    /// CSR grouping of pair rows by drug index (cached; built once).
    pub fn by_drug(&self) -> &GroupBy {
        self.by_drug.get_or_init(|| GroupBy::build(&self.drugs, self.m))
    }

    /// CSR grouping of pair rows by target index (cached; built once).
    pub fn by_target(&self) -> &GroupBy {
        self.by_target.get_or_init(|| GroupBy::build(&self.targets, self.q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PairIndex {
        PairIndex::new(vec![0, 1, 1, 2, 0], vec![2, 0, 1, 2, 0], 3, 3)
    }

    #[test]
    fn swapped_swaps() {
        let p = sample();
        let s = p.swapped();
        for i in 0..p.len() {
            assert_eq!(s.drug(i), p.target(i));
            assert_eq!(s.target(i), p.drug(i));
        }
    }

    #[test]
    fn dupe_drugs_matches_q_rule() {
        let p = sample();
        let d = p.dupe_drugs();
        for i in 0..p.len() {
            assert_eq!(d.drug(i), p.drug(i));
            assert_eq!(d.target(i), p.drug(i));
        }
        assert_eq!(d.q(), p.m());
    }

    #[test]
    fn complete_has_all_pairs() {
        let c = PairIndex::complete(3, 4);
        assert_eq!(c.len(), 12);
        assert_eq!(c.distinct_drugs(), 3);
        assert_eq!(c.distinct_targets(), 4);
        // Row-major order: pair (d, t) lives at index d*q + t.
        for d in 0..3 {
            for t in 0..4 {
                let i = d * 4 + t;
                assert_eq!(c.drug(i), d);
                assert_eq!(c.target(i), t);
            }
        }
    }

    #[test]
    fn subset_picks_rows() {
        let p = sample();
        let s = p.subset(&[4, 2]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.drug(0), 0);
        assert_eq!(s.target(0), 0);
        assert_eq!(s.drug(1), 1);
        assert_eq!(s.target(1), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        PairIndex::new(vec![3], vec![0], 3, 3);
    }
}
