//! The sampling operator `R(d, t)`: a sequence of (drug, target) index
//! pairs. Rows of any pairwise kernel matrix are indexed by such a sample.

use crate::sparse::GroupBy;
use std::sync::{Arc, OnceLock};

/// A sample of `n` (drug, target) pairs over index domains
/// `0..m` (drugs) and `0..q` (targets).
///
/// This is the concrete form of the paper's `R(d, t) ∈ R^{n×(D×T)}`:
/// `drugs[i]` and `targets[i]` give the nonzero column of row `i`.
///
/// The commutation/unification operators of Definition 1 act on samples by
/// index plumbing only (`R(d,t)P = R(t,d)`, `R(d,t)Q = R(d,d)`), exposed
/// here as [`PairIndex::swapped`] and [`PairIndex::dupe_drugs`] /
/// [`PairIndex::dupe_targets`].
///
/// The index buffers are `Arc`-shared: cloning a sample, and every
/// `P`/`Q` transform, is O(1) and allocation-free. An MLPK operator holds
/// 10 transformed samples of its row and column samples — with shared
/// buffers those are views, not copies. The `Arc` identity doubles as the
/// sample-coincidence key used by [`crate::gvt::plan::GvtPlan`] to fuse
/// terms whose stage-1 or stage-2 index streams are byte-identical.
#[derive(Clone, Debug)]
pub struct PairIndex {
    drugs: Arc<Vec<u32>>,
    targets: Arc<Vec<u32>>,
    m: usize,
    q: usize,
    by_drug: OnceLock<Arc<GroupBy>>,
    by_target: OnceLock<Arc<GroupBy>>,
}

impl PairIndex {
    /// Build from parallel index vectors. Panics if any index is out of
    /// range — the coordinator validates data at the boundary.
    pub fn new(drugs: Vec<u32>, targets: Vec<u32>, m: usize, q: usize) -> Self {
        assert_eq!(drugs.len(), targets.len(), "drug/target length mismatch");
        assert!(
            drugs.iter().all(|&d| (d as usize) < m),
            "drug index out of range (m={m})"
        );
        assert!(
            targets.iter().all(|&t| (t as usize) < q),
            "target index out of range (q={q})"
        );
        Self {
            drugs: Arc::new(drugs),
            targets: Arc::new(targets),
            m,
            q,
            by_drug: OnceLock::new(),
            by_target: OnceLock::new(),
        }
    }

    /// The complete sample: every (drug, target) combination, row-major in
    /// drugs (i.e. `vec` ordering of an `m×q` label matrix by rows).
    pub fn complete(m: usize, q: usize) -> Self {
        let n = m * q;
        let mut drugs = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        for d in 0..m as u32 {
            for t in 0..q as u32 {
                drugs.push(d);
                targets.push(t);
            }
        }
        Self::new(drugs, targets, m, q)
    }

    /// Number of pairs `n`.
    #[inline]
    pub fn len(&self) -> usize {
        self.drugs.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.drugs.is_empty()
    }

    /// Number of drug indices in the domain (`m`).
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Number of target indices in the domain (`q`).
    #[inline]
    pub fn q(&self) -> usize {
        self.q
    }

    /// Drug index of pair `i`.
    #[inline]
    pub fn drug(&self, i: usize) -> usize {
        self.drugs[i] as usize
    }

    /// Target index of pair `i`.
    #[inline]
    pub fn target(&self, i: usize) -> usize {
        self.targets[i] as usize
    }

    /// Borrow the raw drug index vector.
    #[inline]
    pub fn drugs(&self) -> &[u32] {
        self.drugs.as_slice()
    }

    /// Borrow the raw target index vector.
    #[inline]
    pub fn targets(&self) -> &[u32] {
        self.targets.as_slice()
    }

    /// Opaque identity of the drug-index buffer (Arc pointer). Two samples
    /// sharing a buffer (via clone or a `P`/`Q` transform) report the same
    /// key; [`crate::gvt::plan::GvtPlan`] uses this to detect coinciding
    /// index streams without comparing contents.
    #[inline]
    pub fn drugs_key(&self) -> usize {
        Arc::as_ptr(&self.drugs) as usize
    }

    /// Opaque identity of the target-index buffer (see [`Self::drugs_key`]).
    #[inline]
    pub fn targets_key(&self) -> usize {
        Arc::as_ptr(&self.targets) as usize
    }

    /// Do two samples index the *same* pairs over the same domains, as
    /// witnessed by shared buffers? (No content comparison: `false` only
    /// means "not provably identical".)
    pub fn same_view(&self, other: &PairIndex) -> bool {
        self.m == other.m
            && self.q == other.q
            && Arc::ptr_eq(&self.drugs, &other.drugs)
            && Arc::ptr_eq(&self.targets, &other.targets)
    }

    /// Do two samples index the same pairs over the same domains? Fast
    /// path via [`Self::same_view`] (shared buffers), falling back to an
    /// `O(n)` content comparison — use this where correctness, not plan
    /// dedup, is at stake (e.g. batching models reloaded from disk whose
    /// buffers are fresh allocations).
    pub fn same_pairs(&self, other: &PairIndex) -> bool {
        self.same_view(other)
            || (self.m == other.m
                && self.q == other.q
                && self.drugs() == other.drugs()
                && self.targets() == other.targets())
    }

    /// `R(d,t) P = R(t,d)` — swap the roles of drugs and targets.
    /// Only meaningful when composed against operators over the matching
    /// domains (homogeneous case, or a `T ⊗ D` term). O(1): buffers are
    /// shared, and already-built groupings carry over with roles swapped.
    pub fn swapped(&self) -> PairIndex {
        PairIndex {
            drugs: self.targets.clone(),
            targets: self.drugs.clone(),
            m: self.q,
            q: self.m,
            by_drug: self.by_target.clone(),
            by_target: self.by_drug.clone(),
        }
    }

    /// `R(d,t) Q = R(d,d)` — duplicate the drug index into both slots.
    /// O(1): both slots share the drug buffer (and its grouping cache).
    pub fn dupe_drugs(&self) -> PairIndex {
        PairIndex {
            drugs: self.drugs.clone(),
            targets: self.drugs.clone(),
            m: self.m,
            q: self.m,
            by_drug: self.by_drug.clone(),
            by_target: self.by_drug.clone(),
        }
    }

    /// `R(d,t) P Q = R(t,t)` — duplicate the target index into both slots.
    pub fn dupe_targets(&self) -> PairIndex {
        PairIndex {
            drugs: self.targets.clone(),
            targets: self.targets.clone(),
            m: self.q,
            q: self.q,
            by_drug: self.by_target.clone(),
            by_target: self.by_target.clone(),
        }
    }

    /// Take the sub-sample at `rows` (for train/test splits).
    pub fn subset(&self, rows: &[usize]) -> PairIndex {
        let drugs = rows.iter().map(|&i| self.drugs[i]).collect();
        let targets = rows.iter().map(|&i| self.targets[i]).collect();
        PairIndex::new(drugs, targets, self.m, self.q)
    }

    /// Number of *distinct* drugs appearing in this sample (≤ m).
    pub fn distinct_drugs(&self) -> usize {
        let mut seen = vec![false; self.m];
        let mut c = 0;
        for &d in self.drugs.iter() {
            if !seen[d as usize] {
                seen[d as usize] = true;
                c += 1;
            }
        }
        c
    }

    /// Number of *distinct* targets appearing in this sample (≤ q).
    pub fn distinct_targets(&self) -> usize {
        let mut seen = vec![false; self.q];
        let mut c = 0;
        for &t in self.targets.iter() {
            if !seen[t as usize] {
                seen[t as usize] = true;
                c += 1;
            }
        }
        c
    }

    /// CSR grouping of pair rows by drug index (cached; built once and
    /// shared across clones/transforms made *after* the build).
    pub fn by_drug(&self) -> &GroupBy {
        self.by_drug
            .get_or_init(|| Arc::new(GroupBy::build(self.drugs.as_slice(), self.m)))
            .as_ref()
    }

    /// CSR grouping of pair rows by target index (cached; built once).
    pub fn by_target(&self) -> &GroupBy {
        self.by_target
            .get_or_init(|| Arc::new(GroupBy::build(self.targets.as_slice(), self.q)))
            .as_ref()
    }

    /// Shared handle to the drug grouping (builds it if needed).
    pub fn by_drug_arc(&self) -> Arc<GroupBy> {
        self.by_drug();
        self.by_drug.get().expect("just initialized").clone()
    }

    /// Shared handle to the target grouping (builds it if needed).
    pub fn by_target_arc(&self) -> Arc<GroupBy> {
        self.by_target();
        self.by_target.get().expect("just initialized").clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PairIndex {
        PairIndex::new(vec![0, 1, 1, 2, 0], vec![2, 0, 1, 2, 0], 3, 3)
    }

    #[test]
    fn swapped_swaps() {
        let p = sample();
        let s = p.swapped();
        for i in 0..p.len() {
            assert_eq!(s.drug(i), p.target(i));
            assert_eq!(s.target(i), p.drug(i));
        }
    }

    #[test]
    fn dupe_drugs_matches_q_rule() {
        let p = sample();
        let d = p.dupe_drugs();
        for i in 0..p.len() {
            assert_eq!(d.drug(i), p.drug(i));
            assert_eq!(d.target(i), p.drug(i));
        }
        assert_eq!(d.q(), p.m());
    }

    #[test]
    fn complete_has_all_pairs() {
        let c = PairIndex::complete(3, 4);
        assert_eq!(c.len(), 12);
        assert_eq!(c.distinct_drugs(), 3);
        assert_eq!(c.distinct_targets(), 4);
        // Row-major order: pair (d, t) lives at index d*q + t.
        for d in 0..3 {
            for t in 0..4 {
                let i = d * 4 + t;
                assert_eq!(c.drug(i), d);
                assert_eq!(c.target(i), t);
            }
        }
    }

    #[test]
    fn subset_picks_rows() {
        let p = sample();
        let s = p.subset(&[4, 2]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.drug(0), 0);
        assert_eq!(s.target(0), 0);
        assert_eq!(s.drug(1), 1);
        assert_eq!(s.target(1), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        PairIndex::new(vec![3], vec![0], 3, 3);
    }

    #[test]
    fn transforms_share_buffers() {
        let p = sample();
        // Clones and transforms alias the original buffers (O(1), no copy).
        assert_eq!(p.clone().drugs_key(), p.drugs_key());
        let sw = p.swapped();
        assert_eq!(sw.drugs_key(), p.targets_key());
        assert_eq!(sw.targets_key(), p.drugs_key());
        let dd = p.dupe_drugs();
        assert_eq!(dd.drugs_key(), p.drugs_key());
        assert_eq!(dd.targets_key(), p.drugs_key());
        // Identical transforms are provably the same view.
        assert!(p.dupe_drugs().same_view(&dd));
        assert!(p.swapped().same_view(&sw));
        assert!(!sw.same_view(&p));
        // A deep copy via new() is NOT provably identical (fresh buffers)
        // — but the content-comparing predicate still recognizes it.
        let fresh = PairIndex::new(p.drugs().to_vec(), p.targets().to_vec(), 3, 3);
        assert!(!fresh.same_view(&p));
        assert!(fresh.same_pairs(&p));
        assert!(!fresh.same_pairs(&p.swapped()));
    }

    #[test]
    fn swapped_inherits_grouping_cache() {
        let p = sample();
        // Build the target grouping, then check the swapped view's drug
        // grouping is the same object (groups of the shared buffer).
        let _ = p.by_target();
        let sw = p.swapped();
        for k in 0..3 {
            assert_eq!(sw.by_drug().group(k), p.by_target().group(k));
        }
    }
}
