//! Miniature property-testing harness (`proptest` is unavailable offline).
//!
//! A property is a closure over a seeded RNG that either passes or returns
//! a failure message. The harness runs `cases` random cases from a master
//! seed and, on failure, reports the *case seed* so the exact case can be
//! replayed with [`replay`]. No shrinking — generators here are asked to
//! start small (case sizes grow with the case index), which keeps failing
//! cases readable in practice.
//!
//! ```
//! use gvt_rls::testing::{property, Prop};
//! use gvt_rls::rng::{Rng, dist};
//!
//! property("addition commutes", 64, |rng, _size| {
//!     let a = rng.next_f64();
//!     let b = rng.next_f64();
//!     Prop::check(a + b == b + a, || format!("{a} + {b}"))
//! });
//! ```

use crate::rng::{child_seeds, Xoshiro256};

/// Result of a single property case.
pub enum Prop {
    Pass,
    Fail(String),
}

impl Prop {
    /// Pass iff `cond`; otherwise build a failure message lazily.
    pub fn check(cond: bool, msg: impl FnOnce() -> String) -> Prop {
        if cond {
            Prop::Pass
        } else {
            Prop::Fail(msg())
        }
    }

    /// Check that two floats agree to `tol` absolute-or-relative.
    pub fn close(a: f64, b: f64, tol: f64, label: &str) -> Prop {
        let scale = a.abs().max(b.abs()).max(1.0);
        Prop::check((a - b).abs() <= tol * scale, || {
            format!("{label}: {a} vs {b} (tol {tol}, scale {scale})")
        })
    }

    /// Check two slices agree elementwise to `tol` (absolute-or-relative).
    pub fn all_close(a: &[f64], b: &[f64], tol: f64, label: &str) -> Prop {
        if a.len() != b.len() {
            return Prop::Fail(format!("{label}: length {} vs {}", a.len(), b.len()));
        }
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let scale = x.abs().max(y.abs()).max(1.0);
            if (x - y).abs() > tol * scale {
                return Prop::Fail(format!(
                    "{label}[{i}]: {x} vs {y} (|Δ|={:.3e}, tol {tol})",
                    (x - y).abs()
                ));
            }
        }
        Prop::Pass
    }
}

/// Run a property over `cases` random cases. `size` grows from 1 to ~32 with
/// the case index so early failures are small. Panics with the case seed on
/// the first failure.
pub fn property<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Xoshiro256, usize) -> Prop,
{
    let master = master_seed();
    let seeds = child_seeds(master, cases);
    for (case, &seed) in seeds.iter().enumerate() {
        let size = 1 + case * 32 / cases.max(1);
        let mut rng = Xoshiro256::seed_from(seed);
        if let Prop::Fail(msg) = prop(&mut rng, size) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay seed: {seed:#x}, size {size}):\n  {msg}"
            );
        }
    }
}

/// Replay one failing case by seed (paste the seed from a failure message).
pub fn replay<F>(name: &str, seed: u64, size: usize, prop: F)
where
    F: Fn(&mut Xoshiro256, usize) -> Prop,
{
    let mut rng = Xoshiro256::seed_from(seed);
    if let Prop::Fail(msg) = prop(&mut rng, size) {
        panic!("replayed property '{name}' (seed {seed:#x}) fails:\n  {msg}");
    }
}

/// Master seed: `GVT_RLS_PROP_SEED` env override for CI reruns, else fixed.
/// A fixed default keeps `cargo test` deterministic; set the env to fuzz.
fn master_seed() -> u64 {
    std::env::var("GVT_RLS_PROP_SEED")
        .ok()
        .and_then(|s| {
            let s = s.trim().trim_start_matches("0x");
            u64::from_str_radix(s, 16).ok().or_else(|| s.parse().ok())
        })
        .unwrap_or(0xC0FF_EE00_5EED_0001)
}

/// Generator helpers shared by property tests across the crate.
pub mod gen {
    use crate::rng::{dist, Rng, Xoshiro256};
    use crate::sparse::PairIndex;

    /// Random symmetric PSD kernel matrix of order `n` (Gram of random
    /// features, ridge-stabilized).
    pub fn psd_kernel(rng: &mut Xoshiro256, n: usize) -> crate::linalg::Mat {
        let r = n.max(2);
        let x = crate::linalg::Mat::from_vec(n, r, dist::normal_vec(rng, n * r));
        let mut k = x.matmul_nt(&x);
        for i in 0..n {
            k[(i, i)] += 1e-3;
        }
        k
    }

    /// Random pair sample: `n` pairs over `m` drugs × `q` targets,
    /// guaranteed to touch every drug and target at least once when
    /// `n >= m + q` (keeps distinct counts predictable in tests).
    pub fn pair_sample(
        rng: &mut Xoshiro256,
        n: usize,
        m: usize,
        q: usize,
    ) -> PairIndex {
        let mut drugs = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        for i in 0..n {
            // First m entries cycle drugs, first q cycle targets: coverage.
            let d = if i < m { i } else { rng.index(m) };
            let t = if i < q { i } else { rng.index(q) };
            drugs.push(d as u32);
            targets.push(t as u32);
        }
        PairIndex::new(drugs, targets, m, q)
    }

    /// Random homogeneous pair sample over `m` objects.
    pub fn homogeneous_sample(rng: &mut Xoshiro256, n: usize, m: usize) -> PairIndex {
        pair_sample(rng, n, m, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn passing_property_runs_all_cases() {
        property("tautology", 16, |rng, _| {
            let _ = rng.next_u64();
            Prop::Pass
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        property("always fails", 4, |_, _| Prop::Fail("nope".into()));
    }

    #[test]
    fn close_handles_relative_scale() {
        assert!(matches!(Prop::close(1e9, 1e9 + 1.0, 1e-6, "x"), Prop::Pass));
        assert!(matches!(Prop::close(1.0, 1.1, 1e-6, "x"), Prop::Fail(_)));
    }

    #[test]
    fn generated_pair_sample_covers_domains() {
        let mut rng = crate::rng::Xoshiro256::seed_from(3);
        let p = gen::pair_sample(&mut rng, 40, 7, 5);
        assert_eq!(p.distinct_drugs(), 7);
        assert_eq!(p.distinct_targets(), 5);
    }
}
