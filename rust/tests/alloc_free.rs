//! Zero-allocation guarantee for solver inner loops: after workspace
//! warmup, `PairwiseLinOp::apply_into` — the entire per-iteration cost of
//! MINRES/CG training — performs **no heap allocation**. Verified with a
//! counting global allocator.
//!
//! The whole file runs with `GVT_RLS_THREADS=1` (set before any
//! parallel-path call; the thread-count cache is process-global, hence
//! the dedicated test binary with a single test): scoped-thread spawns
//! allocate, and forcing the inline path keeps the measurement about the
//! GVT workspace, which is what the guarantee covers — multi-threaded
//! runs allocate only thread stacks, never GVT intermediates.

use std::alloc::{GlobalAlloc, Layout, System};
use std::ops::ControlFlow;
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn solver_iterations_are_allocation_free_after_warmup() {
    std::env::set_var("GVT_RLS_THREADS", "1");

    use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
    use gvt_rls::gvt::vec_trick::GvtPolicy;
    use gvt_rls::rng::{dist, Xoshiro256};
    use gvt_rls::solvers::cg::{cg, CgOptions};
    use gvt_rls::solvers::linear_op::{LinOp, ShiftedOp};
    use gvt_rls::solvers::minres::{minres, MinresOptions};
    use gvt_rls::testing::gen;
    use std::sync::Arc;

    let mut rng = Xoshiro256::seed_from(9);
    let m = 12;
    let n = 60;
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let sample = gen::homogeneous_sample(&mut rng, n, m);
    let a = dist::normal_vec(&mut rng, n);
    let y = dist::normal_vec(&mut rng, n);

    // --- direct apply_into, every kernel (MLPK covers pooled + shared
    // stage-1 + accumulated stage-2; Cartesian covers the misc scratch
    // path) -------------------------------------------------------------
    for kernel in PairwiseKernel::ALL {
        let op = PairwiseLinOp::new(
            kernel,
            d.clone(),
            d.clone(),
            sample.clone(),
            sample.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let mut out = vec![0.0; n];
        // Warmup: sizes the workspace, builds grouping caches, reads the
        // cached env knobs.
        op.apply_into(&a, &mut out);
        op.apply_into(&a, &mut out);
        let before = allocations();
        op.apply_into(&a, &mut out);
        op.apply_into(&a, &mut out);
        let after = allocations();
        assert_eq!(
            after - before,
            0,
            "{kernel:?}: apply_into allocated after warmup"
        );
    }

    // --- MINRES: no allocations between consecutive iterations after
    // the first (workspace-warming) iteration ---------------------------
    let op = PairwiseLinOp::new(
        PairwiseKernel::Mlpk,
        d.clone(),
        d.clone(),
        sample.clone(),
        sample.clone(),
        GvtPolicy::Auto,
    )
    .unwrap();
    let shifted = ShiftedOp::new(&op, 1e-3);
    let mut counts = [0u64; 8];
    let mut last_k = 0usize;
    let _ = minres(
        &shifted,
        &y,
        &MinresOptions { max_iters: 6, rel_tol: 0.0 },
        |k, _x, _rel| {
            if k <= counts.len() {
                counts[k - 1] = allocations();
            }
            last_k = k;
            ControlFlow::Continue(())
        },
    );
    assert!(last_k >= 4, "MINRES stopped too early for the check ({last_k})");
    for k in 2..last_k.min(counts.len()) {
        assert_eq!(
            counts[k],
            counts[k - 1],
            "MINRES iteration {} allocated on the heap",
            k + 1
        );
    }

    // --- CG: same guarantee (K + λI is SPD) ----------------------------
    let mut counts = [0u64; 8];
    let mut last_k = 0usize;
    let _ = cg(
        &shifted,
        &y,
        None,
        &CgOptions { max_iters: 6, rel_tol: 0.0 },
        |k, _x, _rel| {
            if k <= counts.len() {
                counts[k - 1] = allocations();
            }
            last_k = k;
            ControlFlow::Continue(())
        },
    );
    assert!(last_k >= 4, "CG stopped too early for the check ({last_k})");
    for k in 2..last_k.min(counts.len()) {
        assert_eq!(
            counts[k],
            counts[k - 1],
            "CG iteration {} allocated on the heap",
            k + 1
        );
    }
}
