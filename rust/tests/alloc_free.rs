//! Zero-allocation guarantee for solver inner loops: after workspace
//! warmup, `PairwiseLinOp::apply_into` — the entire per-iteration cost of
//! MINRES/CG training — performs **no heap allocation**. Verified with a
//! counting global allocator, twice:
//!
//! 1. **Inline** (`GVT_RLS_THREADS=1`): the historical guarantee — the
//!    GVT workspace itself never allocates after warmup.
//! 2. **Pooled** (thread budget 2 via the runtime's in-process
//!    override): the persistent pool's submission path must not allocate
//!    either — the job header lives on the submitter's stack and the job
//!    queue reuses its capacity, so pooled CG/MINRES iterations are as
//!    allocation-free as inline ones. (The pre-pool scoped path
//!    allocated a thread spawn per parallel region, which is why the old
//!    version of this test could only measure single-threaded runs.)
//!
//! The counting allocator counts allocations from **every** thread, so
//! the pooled section also proves the workers allocate nothing while
//! claiming and executing chunks.
//!
//! The stochastic trainer's hot GVT product is the same plan-executor
//! path measured here (its batch operators share the template's
//! workspace); its per-step operator *derivation* (`with_rows`) does
//! allocate by design and is not part of the guarantee.

use std::alloc::{GlobalAlloc, Layout, System};
use std::ops::ControlFlow;
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: defers every operation to `System` with the caller's
// pointer/layout unchanged, inheriting `GlobalAlloc`'s contract; the
// count is a plain atomic and cannot itself allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards to `System.alloc` with the caller's layout.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: forwards to `System.realloc`; arguments pass through
    // untouched.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: forwards to `System.dealloc` with the caller's pointer and
    // layout untouched.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::linalg::Mat;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::solvers::cg::{cg, CgOptions};
use gvt_rls::solvers::linear_op::{LinOp, ShiftedOp};
use gvt_rls::solvers::minres::{minres, MinresOptions};
use gvt_rls::sparse::PairIndex;
use gvt_rls::testing::gen;
use std::sync::Arc;

/// Run the full apply/MINRES/CG allocation sweep for one runtime
/// configuration (set up by the caller). `label` names the
/// configuration in failure messages.
fn assert_iterations_allocation_free(
    d: &Arc<Mat>,
    sample: &PairIndex,
    a: &[f64],
    y: &[f64],
    label: &str,
) {
    let n = sample.len();

    // --- direct apply_into, every kernel (MLPK covers pooled + shared
    // stage-1 + accumulated stage-2 + the concurrent multi-unit sweep;
    // Cartesian covers the misc scratch path) ---------------------------
    for kernel in PairwiseKernel::ALL {
        let op = PairwiseLinOp::new(
            kernel,
            d.clone(),
            d.clone(),
            sample.clone(),
            sample.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let mut out = vec![0.0; n];
        // Warmup: sizes the workspace (incl. the stage-1 chunk tables),
        // builds grouping caches, reads the cached env knobs, and — in
        // the pooled configuration — spawns/parks the workers.
        op.apply_into(a, &mut out);
        op.apply_into(a, &mut out);
        let before = allocations();
        op.apply_into(a, &mut out);
        op.apply_into(a, &mut out);
        let after = allocations();
        assert_eq!(
            after - before,
            0,
            "{label} / {kernel:?}: apply_into allocated after warmup"
        );
    }

    // --- MINRES: no allocations between consecutive iterations after
    // the first (workspace-warming) iteration ---------------------------
    let op = PairwiseLinOp::new(
        PairwiseKernel::Mlpk,
        d.clone(),
        d.clone(),
        sample.clone(),
        sample.clone(),
        GvtPolicy::Auto,
    )
    .unwrap();
    let shifted = ShiftedOp::new(&op, 1e-3);
    let mut counts = [0u64; 8];
    let mut last_k = 0usize;
    let _ = minres(
        &shifted,
        y,
        &MinresOptions { max_iters: 6, rel_tol: 0.0 },
        |k, _x, _rel| {
            if k <= counts.len() {
                counts[k - 1] = allocations();
            }
            last_k = k;
            ControlFlow::Continue(())
        },
    );
    assert!(last_k >= 4, "{label}: MINRES stopped too early ({last_k})");
    for k in 2..last_k.min(counts.len()) {
        assert_eq!(
            counts[k],
            counts[k - 1],
            "{label}: MINRES iteration {} allocated on the heap",
            k + 1
        );
    }

    // --- CG: same guarantee (K + λI is SPD) ----------------------------
    let mut counts = [0u64; 8];
    let mut last_k = 0usize;
    let _ = cg(
        &shifted,
        y,
        None,
        &CgOptions { max_iters: 6, rel_tol: 0.0 },
        |k, _x, _rel| {
            if k <= counts.len() {
                counts[k - 1] = allocations();
            }
            last_k = k;
            ControlFlow::Continue(())
        },
    );
    assert!(last_k >= 4, "{label}: CG stopped too early ({last_k})");
    for k in 2..last_k.min(counts.len()) {
        assert_eq!(
            counts[k],
            counts[k - 1],
            "{label}: CG iteration {} allocated on the heap",
            k + 1
        );
    }
}

#[test]
fn solver_iterations_are_allocation_free_after_warmup() {
    // Baseline env: single-threaded (read once by the runtime at first
    // use); the pooled section below widens the budget through the
    // runtime's in-process override.
    std::env::set_var("GVT_RLS_THREADS", "1");

    let mut rng = Xoshiro256::seed_from(9);
    let m = 12;
    let n = 60;
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let sample = gen::homogeneous_sample(&mut rng, n, m);
    let a = dist::normal_vec(&mut rng, n);
    let y = dist::normal_vec(&mut rng, n);

    // 1. Inline: the workspace guarantee on the single-threaded path.
    assert_iterations_allocation_free(&d, &sample, &a, &y, "inline(threads=1)");

    // 2. Pooled: persistent pool active, 1 submitter + 1 parked worker.
    // Stage-1 sweeps (12 S rows, ≥ 4 rows per chunk) do fan out, so the
    // pool's submission path and the workers are genuinely exercised.
    // The pool is forced ON explicitly: verify.sh re-runs this suite
    // under GVT_RLS_POOL=0, and the scoped-spawn fallback allocates per
    // region by design — only the pool carries the no-alloc guarantee.
    gvt_rls::runtime::pool::set_num_threads(Some(2));
    gvt_rls::runtime::pool::set_pool_enabled(Some(true));
    gvt_rls::runtime::pool::warm();
    assert_iterations_allocation_free(&d, &sample, &a, &y, "pooled(threads=2)");
    gvt_rls::runtime::pool::set_pool_enabled(None);
    gvt_rls::runtime::pool::set_num_threads(None);
}
