//! Property tests on the coordinator invariants: Table 1 split semantics,
//! CV coverage, determinism, grid-runner routing, config round-trips.

use gvt_rls::data::splits::{cv_splits, split_setting, verify_split_invariant};
use gvt_rls::data::PairDataset;
use gvt_rls::rng::{dist, Rng, Xoshiro256};
use gvt_rls::testing::{gen, property, Prop};
use std::sync::Arc;

fn random_dataset(rng: &mut Xoshiro256, size: usize) -> PairDataset {
    let m = 8 + size;
    let q = 6 + size;
    let n = 4 * (m + q);
    PairDataset {
        name: "prop".into(),
        d: Arc::new(gen::psd_kernel(rng, m)),
        t: Arc::new(gen::psd_kernel(rng, q)),
        pairs: gen::pair_sample(rng, n, m, q),
        y: (0..n).map(|_| if dist::bernoulli(rng, 0.3) { 1.0 } else { 0.0 }).collect(),
        homogeneous: false,
    }
}

#[test]
fn table1_invariants_hold_for_all_settings() {
    property("Table 1 split invariants", 24, |rng, size| {
        let data = random_dataset(rng, size);
        for setting in 1..=4u8 {
            let split = split_setting(&data, setting, 0.3, rng.next_u64());
            if let Err(e) = verify_split_invariant(&split) {
                return Prop::Fail(e);
            }
            // Train + test never exceed the source; labels stay aligned.
            if split.train.len() + split.test.len() > data.len() {
                return Prop::Fail(format!("setting {setting}: split grew the data"));
            }
        }
        Prop::Pass
    });
}

#[test]
fn settings_1_to_3_partition_settings_4_discards() {
    property("partition vs discard", 16, |rng, size| {
        let data = random_dataset(rng, size);
        for setting in 1..=3u8 {
            let split = split_setting(&data, setting, 0.25, rng.next_u64());
            if split.train.len() + split.test.len() != data.len() {
                return Prop::Fail(format!(
                    "setting {setting} must partition: {} + {} != {}",
                    split.train.len(),
                    split.test.len(),
                    data.len()
                ));
            }
        }
        Prop::Pass
    });
}

#[test]
fn cv_test_folds_are_disjoint_and_cover_setting1() {
    property("CV coverage", 12, |rng, size| {
        let data = random_dataset(rng, size);
        let folds = 3 + size % 4;
        let splits = cv_splits(&data, 1, folds, rng.next_u64());
        let total: usize = splits.iter().map(|s| s.test.len()).sum();
        Prop::check(total == data.len(), || {
            format!("setting-1 folds must cover all pairs: {total} vs {}", data.len())
        })
    });
}

#[test]
fn cv_folds_satisfy_invariants_all_settings() {
    property("CV invariants", 8, |rng, size| {
        let data = random_dataset(rng, size);
        for setting in 1..=4u8 {
            for s in cv_splits(&data, setting, 3, rng.next_u64()) {
                if let Err(e) = verify_split_invariant(&s) {
                    return Prop::Fail(format!("setting {setting}: {e}"));
                }
            }
        }
        Prop::Pass
    });
}

#[test]
fn splits_are_deterministic_in_seed() {
    property("split determinism", 12, |rng, size| {
        let data = random_dataset(rng, size);
        let seed = rng.next_u64();
        for setting in 1..=4u8 {
            let a = split_setting(&data, setting, 0.3, seed);
            let b = split_setting(&data, setting, 0.3, seed);
            if a.train.len() != b.train.len()
                || a.test.len() != b.test.len()
                || a.train.pairs.drugs() != b.train.pairs.drugs()
            {
                return Prop::Fail(format!("setting {setting} nondeterministic"));
            }
        }
        Prop::Pass
    });
}

#[test]
fn label_alignment_survives_splitting() {
    property("label alignment", 12, |rng, size| {
        let data = random_dataset(rng, size);
        // Tag each pair with a label encoding its identity.
        let mut tagged = data.clone();
        tagged.y = (0..tagged.len())
            .map(|i| (tagged.pairs.drug(i) * 1000 + tagged.pairs.target(i)) as f64)
            .collect();
        let split = split_setting(&tagged, 2, 0.3, rng.next_u64());
        for part in [&split.train, &split.test] {
            for i in 0..part.len() {
                let expect = (part.pairs.drug(i) * 1000 + part.pairs.target(i)) as f64;
                if part.y[i] != expect {
                    return Prop::Fail(format!("misaligned label at {i}"));
                }
            }
        }
        Prop::Pass
    });
}

#[test]
fn config_parse_roundtrip() {
    use gvt_rls::coordinator::config::Config;
    property("config roundtrip", 16, |rng, _| {
        let lambda = rng.next_f64();
        let folds = 2 + rng.index(10);
        let text = format!("lambda = {lambda}\nfolds = {folds}\nkernel = mlpk\n");
        let c = Config::parse(&text).unwrap();
        if (c.get_f64("lambda", 0.0).unwrap() - lambda).abs() > 1e-12 {
            return Prop::Fail("lambda roundtrip".into());
        }
        if c.get_usize("folds", 0).unwrap() != folds {
            return Prop::Fail("folds roundtrip".into());
        }
        Prop::check(c.get_str("kernel", "") == "mlpk", || "kernel".into())
    });
}

#[test]
fn runner_returns_results_for_every_spec() {
    use gvt_rls::coordinator::{run_grid, ExperimentSpec};
    use gvt_rls::data::metz::MetzConfig;
    use gvt_rls::gvt::pairwise::PairwiseKernel;
    use gvt_rls::solvers::ridge::RidgeConfig;

    let data = MetzConfig::small().generate(33);
    let specs: Vec<ExperimentSpec> = (0..4)
        .map(|i| ExperimentSpec {
            name: format!("cell{i}"),
            data: data.clone(),
            kernel: PairwiseKernel::Linear,
            setting: 1 + (i % 4) as u8,
            folds: 2,
            ridge: RidgeConfig { max_iters: 10, patience: 2, ..Default::default() },
            solver: gvt_rls::solvers::Solver::Minres,
            seed: i as u64,
        })
        .collect();
    let results = run_grid(specs, 3);
    assert_eq!(results.len(), 4);
    for (i, r) in results.iter().enumerate() {
        let r = r.as_ref().unwrap();
        assert_eq!(r.name, format!("cell{i}"));
    }
}

#[test]
fn auc_invariant_under_monotone_score_transforms() {
    use gvt_rls::eval::auc;
    property("AUC monotone invariance", 16, |rng, size| {
        let n = 10 + 4 * size;
        let scores: Vec<f64> = (0..n).map(|_| rng.next_f64() * 4.0 - 2.0).collect();
        let labels: Vec<bool> = (0..n).map(|_| dist::bernoulli(rng, 0.4)).collect();
        if labels.iter().all(|&l| l) || labels.iter().all(|&l| !l) {
            return Prop::Pass;
        }
        let base = auc(&scores, &labels).unwrap();
        // Strictly increasing transforms must not change AUC.
        let scaled: Vec<f64> = scores.iter().map(|s| 3.0 * s + 7.0).collect();
        let exp: Vec<f64> = scores.iter().map(|s| s.exp()).collect();
        for (name, tr) in [("affine", &scaled), ("exp", &exp)] {
            let a = auc(tr, &labels).unwrap();
            if (a - base).abs() > 1e-12 {
                return Prop::Fail(format!("{name}: {a} vs {base}"));
            }
        }
        // Flipping scores must mirror AUC around 0.5.
        let neg: Vec<f64> = scores.iter().map(|s| -s).collect();
        let flipped = auc(&neg, &labels).unwrap();
        Prop::close(flipped, 1.0 - base, 1e-12, "flip")
    });
}

#[test]
fn experiment_results_are_deterministic_across_runs() {
    use gvt_rls::coordinator::{run_cv_experiment, ExperimentSpec};
    use gvt_rls::data::metz::MetzConfig;
    use gvt_rls::gvt::pairwise::PairwiseKernel;
    use gvt_rls::solvers::ridge::RidgeConfig;
    let spec = ExperimentSpec {
        name: "det".into(),
        data: MetzConfig::small().generate(99),
        kernel: PairwiseKernel::Kronecker,
        setting: 2,
        folds: 3,
        ridge: RidgeConfig { max_iters: 15, patience: 3, ..Default::default() },
        solver: gvt_rls::solvers::Solver::Minres,
        seed: 1234,
    };
    let a = run_cv_experiment(&spec).unwrap();
    let b = run_cv_experiment(&spec).unwrap();
    assert_eq!(a.auc.values(), b.auc.values(), "same spec must give same fold AUCs");
    assert_eq!(a.iterations.values(), b.iterations.values());
}
