//! Brute-force oracle tests for the complete-grid eigen shortcut
//! (`solvers/complete.rs`).
//!
//! The leverages LOOCV claims to be *exact*: for every training pair,
//! the closed-form expression `(ŷ − h·y) / (1 − h)` must equal the
//! prediction of a model genuinely retrained without that pair. These
//! tests pay the O(n) retrains (via the `O(n³)` Cholesky oracle in
//! `closed_form.rs`) on small complete grids and demand agreement to
//! 1e-8 — plus α-identity between the eigen solve and converged CG per
//! λ, and the strict iteration win of eigen-preconditioned CG over
//! plain CG on a pinned incomplete-grid fixture.

use gvt_rls::data::PairDataset;
use gvt_rls::gvt::explicit::explicit_matrix;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::linalg::chol::solve_regularized;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::solvers::closed_form::ClosedFormModel;
use gvt_rls::solvers::complete::{check_complete, EigenRidge};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use gvt_rls::solvers::Solver;
use gvt_rls::sparse::PairIndex;
use gvt_rls::testing::gen;
use std::sync::Arc;

/// ≥4 λ values spanning four decades (the acceptance grid).
const LAMBDAS: [f64; 4] = [1e-1, 1.0, 10.0, 100.0];

/// A fully-labeled m×q grid over freshly drawn PSD factor kernels.
fn complete_grid(seed: u64, m: usize, q: usize) -> PairDataset {
    let mut rng = Xoshiro256::seed_from(seed);
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let t = Arc::new(gen::psd_kernel(&mut rng, q));
    let pairs = PairIndex::complete(m, q);
    let y = dist::normal_vec(&mut rng, m * q);
    PairDataset {
        name: format!("grid{m}x{q}"),
        d,
        t,
        pairs,
        y,
        homogeneous: m == q,
    }
}

#[test]
fn eigen_loocv_matches_brute_force_oracle() {
    // Three independent kernel draws (m, q ≤ 12), every pair left out
    // once per λ: the leverages LOOCV must equal an actual retrain.
    for (seed, m, q) in [(910u64, 5usize, 6usize), (911, 7, 5), (912, 6, 8)] {
        let data = complete_grid(seed, m, q);
        let er = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap();
        let cells = er.loocv(&LAMBDAS).unwrap();
        assert_eq!(cells.len(), LAMBDAS.len());
        let n = data.len();
        for cell in &cells {
            for i in 0..n {
                let keep: Vec<usize> = (0..n).filter(|&j| j != i).collect();
                let train = data.subset(&keep);
                let model =
                    ClosedFormModel::fit(&train, PairwiseKernel::Kronecker, cell.lambda)
                        .unwrap();
                let pred = model.predict(&data.pairs.subset(&[i]))[0];
                let diff = (pred - cell.loo[i]).abs();
                assert!(
                    diff <= 1e-8,
                    "seed {seed} λ={} pair {i} ({}, {}): retrained {pred} vs \
                     leverages {} (diff {diff:e})",
                    cell.lambda,
                    data.pairs.drug(i),
                    data.pairs.target(i),
                    cell.loo[i]
                );
            }
        }
    }
}

#[test]
fn eigen_alpha_matches_cg_per_lambda() {
    // The multi-λ eigen solve and a tightly-converged CG must land on
    // the same Tikhonov optimum for every λ in the grid.
    let data = complete_grid(913, 9, 7);
    let er = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap();
    let alphas = er.alpha_grid(&LAMBDAS).unwrap();
    assert_eq!(alphas.len(), LAMBDAS.len());
    for (alpha, &lambda) in alphas.iter().zip(&LAMBDAS) {
        let cfg = RidgeConfig {
            lambda,
            max_iters: 2000,
            rel_tol: 1e-13,
            ..Default::default()
        };
        let cg_model = PairwiseRidge::fit_exact(
            &data,
            PairwiseKernel::Kronecker,
            &cfg,
            cfg.max_iters,
            Solver::Cg,
        )
        .unwrap();
        for (i, (a, b)) in alpha.iter().zip(&cg_model.alpha).enumerate() {
            assert!(
                (a - b).abs() < 1e-6 * (1.0 + b.abs()),
                "λ={lambda} α[{i}]: eigen {a} vs cg {b}"
            );
        }
    }
}

/// Pinned incomplete fixture: 12×12 grid, 116 of 144 cells observed.
fn incomplete_fixture() -> PairDataset {
    let mut rng = Xoshiro256::seed_from(914);
    let d = Arc::new(gen::psd_kernel(&mut rng, 12));
    let t = Arc::new(gen::psd_kernel(&mut rng, 12));
    let chosen = dist::sample_without_replacement(&mut rng, 144, 116);
    let drugs: Vec<u32> = chosen.iter().map(|&c| (c / 12) as u32).collect();
    let targets: Vec<u32> = chosen.iter().map(|&c| (c % 12) as u32).collect();
    let pairs = PairIndex::new(drugs, targets, 12, 12);
    let y = dist::normal_vec(&mut rng, 116);
    PairDataset {
        name: "incomplete12".into(),
        d,
        t,
        pairs,
        y,
        homogeneous: true,
    }
}

#[test]
fn eigen_precond_cg_beats_plain_cg_on_incomplete_grid() {
    let data = incomplete_fixture();
    assert!(
        check_complete(&data.pairs).is_err(),
        "fixture must be an incomplete grid"
    );
    let cfg = RidgeConfig {
        lambda: 1e-2,
        max_iters: 4000,
        rel_tol: 1e-10,
        ..Default::default()
    };
    let plain = PairwiseRidge::fit_exact(
        &data,
        PairwiseKernel::Kronecker,
        &cfg,
        cfg.max_iters,
        Solver::Cg,
    )
    .unwrap();
    let pre =
        PairwiseRidge::fit_eigen_precond_cg(&data, PairwiseKernel::Kronecker, &cfg, cfg.max_iters)
            .unwrap();
    // The acceptance criterion: strictly fewer Krylov iterations.
    assert!(
        pre.iterations < plain.iterations,
        "eigen-preconditioned CG must beat plain CG: {} vs {} iterations",
        pre.iterations,
        plain.iterations
    );
    // Both converge to the same system's solution…
    for (i, (a, b)) in pre.alpha.iter().zip(&plain.alpha).enumerate() {
        assert!(
            (a - b).abs() < 1e-6 * (1.0 + b.abs()),
            "α[{i}]: precond {a} vs plain {b}"
        );
    }
    // …which is the explicit Cholesky optimum.
    let k = explicit_matrix(
        PairwiseKernel::Kronecker,
        &data.d,
        &data.t,
        &data.pairs,
        &data.pairs,
    );
    let oracle = solve_regularized(&k, cfg.lambda, &data.y).unwrap();
    for (i, (a, o)) in pre.alpha.iter().zip(&oracle).enumerate() {
        assert!(
            (a - o).abs() < 1e-6 * (1.0 + o.abs()),
            "α[{i}]: precond {a} vs Cholesky {o}"
        );
    }
}

#[test]
fn eigen_rejects_incomplete_grid_with_missing_count() {
    let data = incomplete_fixture();
    let err = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("incomplete grid"), "{msg}");
    assert!(msg.contains("28 of 144"), "names the missing count: {msg}");
}

#[test]
fn eigen_loocv_selects_a_sane_lambda_on_structured_labels() {
    // Labels with real kernel structure (y = K α* + noise): exact LOOCV
    // must prefer a finite λ over the max-shrinkage corner (which
    // predicts ~0 everywhere), and the winning LOO MSE must beat
    // predicting zero.
    let mut data = complete_grid(915, 8, 8);
    let k = explicit_matrix(
        PairwiseKernel::Kronecker,
        &data.d,
        &data.t,
        &data.pairs,
        &data.pairs,
    );
    let mut rng = Xoshiro256::seed_from(916);
    let alpha_star = dist::normal_vec(&mut rng, data.len());
    let signal = k.matvec(&alpha_star);
    let scale = (signal.iter().map(|s| s * s).sum::<f64>() / signal.len() as f64).sqrt();
    let noise = dist::normal_vec(&mut rng, data.len());
    data.y = signal
        .iter()
        .zip(&noise)
        .map(|(s, e)| s / scale + 0.1 * e)
        .collect();

    let er = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap();
    let grid = [1e-2, 1e-1, 1.0, 10.0, 1e6];
    let cells = er.loocv(&grid).unwrap();
    let best = cells
        .iter()
        .min_by(|a, b| a.mse.partial_cmp(&b.mse).unwrap())
        .unwrap();
    assert!(best.lambda < 1e6, "LOOCV picked the degenerate max-λ corner");
    let var = data.y.iter().map(|y| y * y).sum::<f64>() / data.len() as f64;
    assert!(
        best.mse < var,
        "LOO MSE {} no better than predicting zero ({var})",
        best.mse
    );
}
