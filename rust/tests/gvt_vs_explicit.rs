//! The central correctness contract of the paper: for every pairwise
//! kernel, the GVT term-sum mat-vec (Corollary 1) must equal the explicit
//! Table 3 kernel-matrix product — on training matrices, cross
//! (prediction) matrices, heterogeneous and homogeneous domains, and all
//! factorization policies.

use gvt_rls::gvt::explicit::explicit_matrix;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::linalg::vecops;
use gvt_rls::rng::dist;
use gvt_rls::testing::{gen, property, Prop};
use std::sync::Arc;

fn check_kernel(
    kernel: PairwiseKernel,
    policy: GvtPolicy,
    rng: &mut gvt_rls::rng::Xoshiro256,
    size: usize,
) -> Prop {
    // Homogeneous domain sized by the property harness's growth schedule.
    let m = 3 + size;
    let hetero = kernel.supports_heterogeneous();
    let q = if hetero { 2 + size / 2 } else { m };
    let d = Arc::new(gen::psd_kernel(rng, m));
    let t = if hetero { Arc::new(gen::psd_kernel(rng, q)) } else { d.clone() };
    let n = 10 + 4 * size;
    let nbar = 5 + 2 * size;
    let cols = gen::pair_sample(rng, n, m, q);
    let rows = gen::pair_sample(rng, nbar, m, q);
    let a = dist::normal_vec(rng, n);

    let op = PairwiseLinOp::new(kernel, d.clone(), t.clone(), rows.clone(), cols.clone(), policy)
        .unwrap();
    let fast = op.matvec(&a);
    let k = explicit_matrix(kernel, &d, &t, &rows, &cols);
    let slow = k.matvec(&a);
    Prop::all_close(&fast, &slow, 1e-8, &format!("{kernel:?}/{policy:?}"))
}

#[test]
fn all_kernels_match_explicit_all_policies() {
    for kernel in PairwiseKernel::ALL {
        for policy in [GvtPolicy::Auto, GvtPolicy::SparseLeft, GvtPolicy::SparseRight, GvtPolicy::Dense]
        {
            property(
                &format!("{kernel:?} GVT == explicit ({policy:?})"),
                12,
                |rng, size| check_kernel(kernel, policy, rng, size),
            );
        }
    }
}

#[test]
fn training_matrix_case_rows_equal_cols() {
    property("training op symmetric vs explicit", 16, |rng, size| {
        let m = 4 + size;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let s = gen::homogeneous_sample(rng, 12 + 3 * size, m);
        let a = dist::normal_vec(rng, s.len());
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                s.clone(),
                s.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let fast = op.matvec(&a);
            let k = explicit_matrix(kernel, &d, &d, &s, &s);
            let slow = k.matvec(&a);
            if let Prop::Fail(msg) = Prop::all_close(&fast, &slow, 1e-8, kernel.name()) {
                return Prop::Fail(msg);
            }
        }
        Prop::Pass
    });
}

#[test]
fn entry_accessor_matches_explicit_entry() {
    property("PairwiseLinOp::entry == Table 3 entry", 20, |rng, size| {
        let m = 4 + size / 2;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let rows = gen::homogeneous_sample(rng, 10, m);
        let cols = gen::homogeneous_sample(rng, 10, m);
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                rows.clone(),
                cols.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let k = explicit_matrix(kernel, &d, &d, &rows, &cols);
            for i in 0..rows.len() {
                for j in 0..cols.len() {
                    let a = op.entry(i, j);
                    let b = k[(i, j)];
                    if (a - b).abs() > 1e-9 * (1.0 + b.abs()) {
                        return Prop::Fail(format!("{kernel:?} entry ({i},{j}): {a} vs {b}"));
                    }
                }
            }
        }
        Prop::Pass
    });
}

#[test]
fn ranking_incidence_shortcut_matches_gvt() {
    // §4.6: the MᵀDM incidence shortcut and the (I−P)(D⊗1)(I−P) GVT
    // decomposition are the same operator.
    property("incidence == GVT ranking", 16, |rng, size| {
        let m = 4 + size;
        let d = gen::psd_kernel(rng, m);
        let s = gen::homogeneous_sample(rng, 12 + 2 * size, m);
        let a = dist::normal_vec(rng, s.len());
        let inc = gvt_rls::sparse::Incidence::from_pairs(&s);
        let p1 = inc.ranking_matvec(&d, &a);
        let op = PairwiseLinOp::new(
            PairwiseKernel::Ranking,
            Arc::new(d.clone()),
            Arc::new(d),
            s.clone(),
            s,
            GvtPolicy::Auto,
        )
        .unwrap();
        let p2 = op.matvec(&a);
        Prop::all_close(&p1, &p2, 1e-8, "ranking")
    });
}

#[test]
fn term_counts_are_the_papers() {
    // Fig 7 discussion: Kronecker 1 summand … MLPK 10 summands.
    let counts: Vec<(PairwiseKernel, usize)> =
        PairwiseKernel::ALL.iter().map(|k| (*k, k.terms().len())).collect();
    let expect = [
        (PairwiseKernel::Linear, 2),
        (PairwiseKernel::Poly2D, 3),
        (PairwiseKernel::Kronecker, 1),
        (PairwiseKernel::Cartesian, 2),
        (PairwiseKernel::Symmetric, 2),
        (PairwiseKernel::AntiSymmetric, 2),
        (PairwiseKernel::Ranking, 4),
        (PairwiseKernel::Mlpk, 10),
    ];
    for (k, c) in expect {
        assert!(counts.contains(&(k, c)), "{k:?} should have {c} terms, got {counts:?}");
    }
}

#[test]
fn gaussian_base_kernels_make_kronecker_the_gaussian_pairwise_kernel() {
    // §4.3: the pairwise Gaussian kernel on concatenated features equals
    // the Kronecker product of per-object Gaussian kernels.
    use gvt_rls::kernels::{cross_kernel_matrix, BaseKernel, KernelParams};
    use gvt_rls::linalg::Mat;
    let mut rng = gvt_rls::rng::Xoshiro256::seed_from(7);
    let m = 5;
    let q = 4;
    let fd = Mat::from_vec(m, 3, dist::normal_vec(&mut rng, m * 3));
    let ft = Mat::from_vec(q, 3, dist::normal_vec(&mut rng, q * 3));
    let params = KernelParams { gamma: 0.3, ..Default::default() };
    let d = cross_kernel_matrix(BaseKernel::Gaussian, &params, &fd, &fd);
    let t = cross_kernel_matrix(BaseKernel::Gaussian, &params, &ft, &ft);
    let rows = gen::pair_sample(&mut rng, 12, m, q);
    let k = explicit_matrix(PairwiseKernel::Kronecker, &d, &t, &rows, &rows);
    // Direct pairwise Gaussian on concatenated features.
    for i in 0..rows.len() {
        for j in 0..rows.len() {
            let (di, ti) = (rows.drug(i), rows.target(i));
            let (dj, tj) = (rows.drug(j), rows.target(j));
            let mut d2 = 0.0;
            for c in 0..3 {
                let x = fd[(di, c)] - fd[(dj, c)];
                let y = ft[(ti, c)] - ft[(tj, c)];
                d2 += x * x + y * y;
            }
            let direct = (-0.3 * d2).exp();
            assert!(
                (k[(i, j)] - direct).abs() < 1e-10,
                "({i},{j}): {} vs {direct}",
                k[(i, j)]
            );
        }
    }
}

#[test]
fn naive_and_gvt_agree_on_rectangular_cross_kernels() {
    property("cross-kernel prediction matvec", 12, |rng, size| {
        let m = 4 + size;
        let q = 3 + size / 2;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let t = Arc::new(gen::psd_kernel(rng, q));
        let train = gen::pair_sample(rng, 20 + 2 * size, m, q);
        let test = gen::pair_sample(rng, 10 + size, m, q);
        let a = dist::normal_vec(rng, train.len());
        for kernel in [PairwiseKernel::Kronecker, PairwiseKernel::Linear, PairwiseKernel::Poly2D] {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                t.clone(),
                test.clone(),
                train.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let fast = op.matvec(&a);
            let k = explicit_matrix(kernel, &d, &t, &test, &train);
            let slow = k.matvec(&a);
            let err = vecops::max_abs_diff(&fast, &slow);
            if err > 1e-8 {
                return Prop::Fail(format!("{kernel:?}: err {err}"));
            }
        }
        Prop::Pass
    });
}

#[test]
fn pairwise_kernels_are_positive_semidefinite() {
    // Random quadratic forms aᵀKa ≥ 0 for every PSD-claimed kernel built
    // on PSD base kernels (anti-symmetric included: its feature map
    // √½(x⊗x' − x'⊗x) is real, so the kernel is PSD too).
    property("pairwise kernels PSD", 16, |rng, size| {
        let m = 4 + size / 2;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let s = gen::homogeneous_sample(rng, 10 + 2 * size, m);
        let a = dist::normal_vec(rng, s.len());
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                s.clone(),
                s.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let ka = op.matvec(&a);
            let quad: f64 = a.iter().zip(&ka).map(|(x, y)| x * y).sum();
            // Linear can be indefinite only if base kernels are not PSD;
            // with PSD bases all of Table 3 is PSD.
            if quad < -1e-6 * ka.iter().map(|x| x.abs()).sum::<f64>().max(1.0) {
                return Prop::Fail(format!("{kernel:?}: aᵀKa = {quad}"));
            }
        }
        Prop::Pass
    });
}

#[test]
fn prediction_operator_is_adjoint_of_reverse_operator() {
    // <K_{test,train} a, b> == <a, K_{train,test} b> — the cross-kernel
    // operators must be transposes of each other (prediction correctness
    // depends on it).
    property("cross op adjointness", 12, |rng, size| {
        let m = 4 + size;
        let q = 3 + size;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let t = Arc::new(gen::psd_kernel(rng, q));
        let train = gen::pair_sample(rng, 15 + 2 * size, m, q);
        let test = gen::pair_sample(rng, 8 + size, m, q);
        let a = dist::normal_vec(rng, train.len());
        let b = dist::normal_vec(rng, test.len());
        for kernel in [PairwiseKernel::Kronecker, PairwiseKernel::Poly2D, PairwiseKernel::Linear]
        {
            let fwd = PairwiseLinOp::new(
                kernel,
                d.clone(),
                t.clone(),
                test.clone(),
                train.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let rev = PairwiseLinOp::new(
                kernel,
                d.clone(),
                t.clone(),
                train.clone(),
                test.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let lhs: f64 = fwd.matvec(&a).iter().zip(&b).map(|(x, y)| x * y).sum();
            let rhs: f64 = a.iter().zip(rev.matvec(&b)).map(|(x, y)| x * y).sum();
            if (lhs - rhs).abs() > 1e-8 * lhs.abs().max(1.0) {
                return Prop::Fail(format!("{kernel:?}: {lhs} vs {rhs}"));
            }
        }
        Prop::Pass
    });
}
