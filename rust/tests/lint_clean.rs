//! The shipped tree passes its own static-analysis pass.
//!
//! `gvt-rls lint` walks rust/src, rust/tests, rust/benches, and
//! examples/ and enforces the five source-level contracts (determinism,
//! hot-path allocation, unsafe audit, env-var registry, panic surface —
//! see rust/DESIGN.md §Static analysis). This test runs the same pass
//! in-process so `cargo test` fails the moment a violation lands,
//! without waiting for scripts/verify.sh.
//!
//! The per-rule behavior (that seeded violations ARE caught) is pinned
//! by the unit fixtures in src/lint/rules.rs; this test pins the other
//! direction — the real tree is clean.

use std::path::Path;

#[test]
fn shipped_tree_has_no_lint_findings() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.parent().expect("rust/ has a parent directory");
    let report = gvt_rls::lint::lint_repo(root, &[]).expect("lint walks the tree");
    assert!(
        report.findings.is_empty(),
        "gvt-lint findings on the shipped tree:\n{}",
        report.render_text()
    );
    // Guard against the walk silently going blind (wrong root, glob
    // regression): the crate is far bigger than this.
    assert!(
        report.files_scanned > 80,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
}
