//! The micro-kernel determinism contract: every tiled chunk body
//! (`linalg::microkernel`) produces **bit-identical** output to the
//! `GVT_RLS_MICROKERNEL=0` scalar fallback, across all 8 pairwise
//! kernels × thread budgets {1, 2, 8} × pool {off, on} (the
//! pool_determinism sweep), plus shape-edge cases where rows/cols land on
//! every residue of the 4/8-wide tiles.
//!
//! The one documented exception is the Gaussian Gram builder: the tiled
//! path assembles `exp(-γ(‖x_i‖² + ‖x_j‖² − 2⟨x_i,x_j⟩))` from squared
//! norms + dot tiles, which is algebraically but not bitwise equal to the
//! per-entry `(x−y)²` sum — asserted to tolerance instead (rust/DESIGN.md
//! §Micro-Kernels).
//!
//! One `#[test]` only: the microkernel/pool/thread overrides are
//! process-global, and libtest runs sibling tests concurrently.

use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::kernels::{cross_kernel_matrix, kernel_matrix, BaseKernel, KernelParams};
use gvt_rls::linalg::{microkernel, Mat};
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::runtime::pool;
use gvt_rls::solvers::linear_op::{LinOp, ShiftedOp};
use gvt_rls::solvers::minres::{minres, MinresOptions};
use gvt_rls::testing::gen;
use std::ops::ControlFlow;
use std::sync::Arc;

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Run `f` with the micro-kernels forced off, then on; return both.
fn ab<T>(mut f: impl FnMut() -> T) -> (T, T) {
    microkernel::set_enabled(Some(false));
    let off = f();
    microkernel::set_enabled(Some(true));
    let on = f();
    (off, on)
}

#[test]
fn microkernels_are_bit_identical_to_scalar_paths() {
    let mut rng = Xoshiro256::seed_from(2024);

    // ------------------------------------------------------------------
    // Mat-level shape sweep: every residue of the 4-row GEMV tile, the
    // 4×8 GEMM tile, and the 1×4 NT tile, plus empty/degenerate shapes.
    // ------------------------------------------------------------------
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 5),
        (3, 8, 9),
        (4, 16, 8),
        (5, 17, 7),
        (6, 9, 33),
        (7, 31, 2),
        (8, 8, 8),
        (9, 24, 17),
        (12, 40, 12),
        (16, 33, 16),
        (17, 64, 41),
        (33, 100, 29),
        (0, 5, 4),
        (4, 0, 3),
        (5, 7, 0),
    ];
    for &(m, k, n) in shapes {
        let a = Mat::from_vec(m, k, dist::normal_vec(&mut rng, m * k));
        let b = Mat::from_vec(k, n, dist::normal_vec(&mut rng, k * n));
        let bt = Mat::from_vec(n, k, dist::normal_vec(&mut rng, n * k));
        let x = dist::normal_vec(&mut rng, k);
        let (mm_off, mm_on) = ab(|| a.matmul(&b));
        assert_eq!(
            bits(mm_off.as_slice()),
            bits(mm_on.as_slice()),
            "matmul ({m},{k},{n})"
        );
        let (mv_off, mv_on) = ab(|| a.matvec(&x));
        assert_eq!(bits(&mv_off), bits(&mv_on), "matvec ({m},{k})");
        let (nt_off, nt_on) = ab(|| a.matmul_nt(&bt));
        assert_eq!(
            bits(nt_off.as_slice()),
            bits(nt_on.as_slice()),
            "matmul_nt ({m},{k},{n})"
        );
    }

    // Sparse A exercises the panel-occupancy escape against the
    // branch-free scalar fallback (the historical skip-zero loop's bits).
    {
        let mut adata = dist::normal_vec(&mut rng, 48 * 300);
        for (i, v) in adata.iter_mut().enumerate() {
            if i % 23 != 0 {
                *v = 0.0;
            }
        }
        let a = Mat::from_vec(48, 300, adata);
        let b = Mat::from_vec(300, 19, dist::normal_vec(&mut rng, 300 * 19));
        let (off, on) = ab(|| a.matmul(&b));
        assert_eq!(bits(off.as_slice()), bits(on.as_slice()), "sparse-panel GEMM");
    }

    // ------------------------------------------------------------------
    // Gram builders: linear/polynomial bitwise, Gaussian to tolerance,
    // combinatorial kernels share one code path (still asserted).
    // ------------------------------------------------------------------
    let params = KernelParams { gamma: 0.37, degree: 3, coef0: 0.5 };
    for n in [1usize, 5, 9, 16, 23] {
        let x = Mat::from_vec(n, 13, dist::normal_vec(&mut rng, n * 13));
        let y = Mat::from_vec(7, 13, dist::normal_vec(&mut rng, 7 * 13));
        for kern in [
            BaseKernel::Linear,
            BaseKernel::Polynomial,
            BaseKernel::Tanimoto,
            BaseKernel::Min,
            BaseKernel::Cosine,
        ] {
            let (off, on) = ab(|| kernel_matrix(kern, &params, &x));
            assert_eq!(
                bits(off.as_slice()),
                bits(on.as_slice()),
                "kernel_matrix {kern:?} n={n}"
            );
            let (coff, con) = ab(|| cross_kernel_matrix(kern, &params, &x, &y));
            assert_eq!(
                bits(coff.as_slice()),
                bits(con.as_slice()),
                "cross_kernel_matrix {kern:?} n={n}"
            );
        }
        let (goff, gon) = ab(|| kernel_matrix(BaseKernel::Gaussian, &params, &x));
        assert!(
            goff.max_abs_diff(&gon) < 1e-12,
            "gaussian kernel_matrix n={n}: {}",
            goff.max_abs_diff(&gon)
        );
        assert!(gon.is_symmetric(0.0), "gaussian gram not exactly symmetric");
        for i in 0..n {
            assert_eq!(gon[(i, i)], 1.0, "gaussian diagonal n={n} i={i}");
        }
        let (gcoff, gcon) = ab(|| cross_kernel_matrix(BaseKernel::Gaussian, &params, &x, &y));
        assert!(gcoff.max_abs_diff(&gcon) < 1e-12, "gaussian cross n={n}");
    }

    // ------------------------------------------------------------------
    // Operator-level sweep: all 8 pairwise kernels × threads {1,2,8} ×
    // pool {off,on}. Baseline = scalar path, single thread, scoped
    // fallback; every configuration × both micro-kernel settings must
    // reproduce it bit-for-bit (matvec twice for warm-workspace reuse,
    // plus the multi-RHS matmat).
    // ------------------------------------------------------------------
    let m = 24;
    let n = 300;
    let nbar = 180;
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let cols = gen::homogeneous_sample(&mut rng, n, m);
    let rows = gen::homogeneous_sample(&mut rng, nbar, m);
    let av = dist::normal_vec(&mut rng, n);
    let rhs: Vec<Vec<f64>> = (0..3).map(|_| dist::normal_vec(&mut rng, n)).collect();
    let refs: Vec<&[f64]> = rhs.iter().map(|v| v.as_slice()).collect();
    let abm = Mat::from_columns(&refs);

    let run = |kernel: PairwiseKernel| -> (Vec<u64>, Vec<u64>) {
        let op = PairwiseLinOp::new(
            kernel,
            d.clone(),
            d.clone(),
            rows.clone(),
            cols.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let mut out = vec![0.0; nbar];
        op.apply_into(&av, &mut out);
        op.apply_into(&av, &mut out);
        let mm = op.matmat(&abm);
        (bits(&out), bits(mm.as_slice()))
    };

    pool::set_num_threads(Some(1));
    pool::set_pool_enabled(Some(false));
    microkernel::set_enabled(Some(false));
    let baseline: Vec<(PairwiseKernel, (Vec<u64>, Vec<u64>))> =
        PairwiseKernel::ALL.iter().map(|&k| (k, run(k))).collect();

    for threads in [1usize, 2, 8] {
        for pool_on in [false, true] {
            for mk_on in [false, true] {
                pool::set_num_threads(Some(threads));
                pool::set_pool_enabled(Some(pool_on));
                microkernel::set_enabled(Some(mk_on));
                for (kernel, (base_mv, base_mm)) in &baseline {
                    let (mv, mm) = run(*kernel);
                    assert_eq!(
                        &mv, base_mv,
                        "{kernel:?} threads={threads} pool={pool_on} mk={mk_on}: matvec bits"
                    );
                    assert_eq!(
                        &mm, base_mm,
                        "{kernel:?} threads={threads} pool={pool_on} mk={mk_on}: matmat bits"
                    );
                }
            }
        }
    }
    pool::set_num_threads(None);
    pool::set_pool_enabled(None);

    // ------------------------------------------------------------------
    // Solver-level: a fixed-iteration MINRES ridge solve must produce the
    // same bits either way (the iterates are compositions of the paths
    // pinned above; this pins the composition end to end).
    // ------------------------------------------------------------------
    let sq_op = PairwiseLinOp::new(
        PairwiseKernel::Kronecker,
        d.clone(),
        d.clone(),
        cols.clone(),
        cols.clone(),
        GvtPolicy::Auto,
    )
    .unwrap();
    let shifted = ShiftedOp::new(&sq_op, 1e-2);
    let y: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();
    let opts = MinresOptions { max_iters: 12, rel_tol: 0.0 };
    let (sol_off, sol_on) = ab(|| {
        minres(&shifted, &y, &opts, |_, _, _| ControlFlow::Continue(()))
            .unwrap()
            .x
    });
    assert_eq!(bits(&sol_off), bits(&sol_on), "MINRES solve bits");

    microkernel::set_enabled(None);
}
