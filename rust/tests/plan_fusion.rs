//! Fused-plan integration properties: the compiled [`GvtPlan`] execution
//! must be indistinguishable from (a) the isolated per-term path and
//! (b) the `O(terms)` scalar entry oracle, for every kernel, on
//! homogeneous and heterogeneous samples; the multi-RHS block product
//! must equal a column loop; and workspace reuse must be idempotent.

use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::plan::gvt_matmat;
use gvt_rls::gvt::vec_trick::{gvt_matvec, GvtPolicy};
use gvt_rls::linalg::Mat;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::solvers::linear_op::LinOp;
use gvt_rls::testing::{gen, property, Prop};
use std::sync::Arc;

/// `K a` via the per-entry scalar oracle — independent of both GVT paths.
fn entry_oracle(op: &PairwiseLinOp, a: &[f64]) -> Vec<f64> {
    let nbar = op.rows().len();
    let n = op.cols().len();
    let mut out = vec![0.0; nbar];
    for i in 0..nbar {
        let mut acc = 0.0;
        for j in 0..n {
            acc += op.entry(i, j) * a[j];
        }
        out[i] = acc;
    }
    out
}

#[test]
fn fused_matches_unfused_and_oracle_homogeneous() {
    property("fused == unfused == oracle (homogeneous)", 20, |rng, size| {
        let m = 3 + size / 4;
        let n = 4 + size * 3;
        let nbar = 3 + size * 2;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let rows = gen::homogeneous_sample(rng, nbar, m);
        let cols = gen::homogeneous_sample(rng, n, m);
        let a = dist::normal_vec(rng, n);
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                rows.clone(),
                cols.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let fused = op.matvec(&a);
            let mut unfused = vec![0.0; nbar];
            op.matvec_into_unfused(&a, &mut unfused);
            let oracle = entry_oracle(&op, &a);
            if let p @ Prop::Fail(_) = Prop::all_close(
                &fused,
                &unfused,
                1e-9,
                &format!("{kernel:?}: fused vs unfused"),
            ) {
                return p;
            }
            if let p @ Prop::Fail(_) = Prop::all_close(
                &fused,
                &oracle,
                1e-8,
                &format!("{kernel:?}: fused vs entry oracle"),
            ) {
                return p;
            }
        }
        Prop::Pass
    });
}

#[test]
fn fused_matches_unfused_and_oracle_heterogeneous() {
    property("fused == unfused == oracle (heterogeneous)", 20, |rng, size| {
        let m = 3 + size / 3;
        let q = 2 + size / 2;
        let n = 4 + size * 3;
        let nbar = 3 + size * 2;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let t = Arc::new(gen::psd_kernel(rng, q));
        let rows = gen::pair_sample(rng, nbar, m, q);
        let cols = gen::pair_sample(rng, n, m, q);
        let a = dist::normal_vec(rng, n);
        for kernel in PairwiseKernel::ALL {
            if !kernel.supports_heterogeneous() {
                continue;
            }
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                t.clone(),
                rows.clone(),
                cols.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let fused = op.matvec(&a);
            let mut unfused = vec![0.0; nbar];
            op.matvec_into_unfused(&a, &mut unfused);
            let oracle = entry_oracle(&op, &a);
            if let p @ Prop::Fail(_) = Prop::all_close(
                &fused,
                &unfused,
                1e-9,
                &format!("{kernel:?}: fused vs unfused"),
            ) {
                return p;
            }
            if let p @ Prop::Fail(_) = Prop::all_close(
                &fused,
                &oracle,
                1e-8,
                &format!("{kernel:?}: fused vs entry oracle"),
            ) {
                return p;
            }
        }
        Prop::Pass
    });
}

#[test]
fn operator_matmat_matches_column_loop() {
    property("matmat == column loop (all kernels)", 12, |rng, size| {
        let m = 3 + size / 4;
        let n = 6 + size * 2;
        let nbar = 4 + size;
        let b = 1 + size % 5;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let rows = gen::homogeneous_sample(rng, nbar, m);
        let cols = gen::homogeneous_sample(rng, n, m);
        let columns: Vec<Vec<f64>> = (0..b).map(|_| dist::normal_vec(rng, n)).collect();
        let refs: Vec<&[f64]> = columns.iter().map(|v| v.as_slice()).collect();
        let ab = Mat::from_columns(&refs);
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                rows.clone(),
                cols.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let block = op.matmat(&ab);
            for (bb, col) in columns.iter().enumerate() {
                let single = op.matvec(col);
                if let p @ Prop::Fail(_) = Prop::all_close(
                    &block.column(bb),
                    &single,
                    1e-9,
                    &format!("{kernel:?}: matmat col {bb}"),
                ) {
                    return p;
                }
            }
        }
        Prop::Pass
    });
}

#[test]
fn free_gvt_matmat_matches_column_loop() {
    property("gvt_matmat == per-column gvt_matvec", 16, |rng, size| {
        let m = 3 + size / 3;
        let q = 2 + size / 2;
        let n = 5 + size * 2;
        let nbar = 4 + size;
        let b = 1 + size % 4;
        let am = gen::psd_kernel(rng, m);
        let bm = gen::psd_kernel(rng, q);
        let rows = gen::pair_sample(rng, nbar, m, q);
        let cols = gen::pair_sample(rng, n, m, q);
        let columns: Vec<Vec<f64>> = (0..b).map(|_| dist::normal_vec(rng, n)).collect();
        let refs: Vec<&[f64]> = columns.iter().map(|v| v.as_slice()).collect();
        let ab = Mat::from_columns(&refs);
        for policy in [GvtPolicy::Auto, GvtPolicy::SparseLeft, GvtPolicy::SparseRight] {
            let block = gvt_matmat(&am, &bm, &rows, &cols, &ab, policy);
            for (bb, col) in columns.iter().enumerate() {
                let single = gvt_matvec(&am, &bm, &rows, &cols, col, policy);
                if let p @ Prop::Fail(_) = Prop::all_close(
                    &block.column(bb),
                    &single,
                    1e-9,
                    &format!("{policy:?}: col {bb}"),
                ) {
                    return p;
                }
            }
        }
        Prop::Pass
    });
}

/// Two consecutive `apply_into` calls through the operator-owned
/// workspace must give bit-identical results (buffers are fully
/// overwritten, never accumulated across calls).
#[test]
fn workspace_reuse_identical_results() {
    let mut rng = Xoshiro256::seed_from(77);
    let m = 10;
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let sample = gen::homogeneous_sample(&mut rng, 60, m);
    let a = dist::normal_vec(&mut rng, 60);
    for kernel in PairwiseKernel::ALL {
        let op = PairwiseLinOp::new(
            kernel,
            d.clone(),
            d.clone(),
            sample.clone(),
            sample.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let mut y1 = vec![0.0; 60];
        let mut y2 = vec![f64::NAN; 60]; // dirty output buffer
        op.apply_into(&a, &mut y1);
        op.apply_into(&a, &mut y2);
        assert_eq!(y1, y2, "{kernel:?}: workspace reuse changed the result");
    }
}
