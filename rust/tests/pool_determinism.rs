//! The runtime-pool determinism contract: `GvtPlan` execution produces
//! **bit-identical** output for every worker count and for both
//! execution paths (persistent pool vs the `GVT_RLS_POOL=0` scoped
//! fallback). This is the property that makes the pool safe to share
//! across solvers and the serving dispatcher: the scheduler may change
//! *when and where* an output row is computed, never *what* is computed.
//!
//! Covers all 8 pairwise kernels (MLPK exercises the concurrent
//! multi-unit stage-1 sweep, Ranking the pooled terms, Cartesian the
//! misc path), the single-RHS `apply_into` path and the multi-RHS
//! `matmat` path, across thread budgets {1, 2, 8} × pool {off, on} —
//! every configuration must reproduce the (threads=1, pool=off)
//! baseline bit-for-bit.
//!
//! One `#[test]` only: the runtime overrides are process-global, and
//! libtest runs sibling tests concurrently.

use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::linalg::Mat;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::runtime::pool;
use gvt_rls::solvers::linear_op::LinOp;
use gvt_rls::testing::gen;
use std::sync::Arc;

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn plan_execution_is_bit_identical_across_runtime_configs() {
    let mut rng = Xoshiro256::seed_from(77);
    let m = 24;
    let n = 300;
    let nbar = 180;
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let cols = gen::homogeneous_sample(&mut rng, n, m);
    let rows = gen::homogeneous_sample(&mut rng, nbar, m);
    let a = dist::normal_vec(&mut rng, n);
    let rhs: Vec<Vec<f64>> = (0..3).map(|_| dist::normal_vec(&mut rng, n)).collect();
    let refs: Vec<&[f64]> = rhs.iter().map(|v| v.as_slice()).collect();
    let ab = Mat::from_columns(&refs);

    let run = |kernel: PairwiseKernel| -> (Vec<u64>, Vec<u64>) {
        let op = PairwiseLinOp::new(
            kernel,
            d.clone(),
            d.clone(),
            rows.clone(),
            cols.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let mut out = vec![0.0; nbar];
        // Apply twice: warm-workspace re-execution must not change bits.
        op.apply_into(&a, &mut out);
        op.apply_into(&a, &mut out);
        let mm = op.matmat(&ab);
        (bits(&out), bits(mm.as_slice()))
    };

    // Reference bits: single-threaded, scoped fallback (the pre-pool
    // execution semantics).
    pool::set_num_threads(Some(1));
    pool::set_pool_enabled(Some(false));
    let baseline: Vec<(PairwiseKernel, (Vec<u64>, Vec<u64>))> =
        PairwiseKernel::ALL.iter().map(|&k| (k, run(k))).collect();

    for threads in [1usize, 2, 8] {
        for pool_on in [false, true] {
            pool::set_num_threads(Some(threads));
            pool::set_pool_enabled(Some(pool_on));
            for (kernel, (base_mv, base_mm)) in &baseline {
                let (mv, mm) = run(*kernel);
                assert_eq!(
                    &mv, base_mv,
                    "{kernel:?} threads={threads} pool={pool_on}: matvec bits differ"
                );
                assert_eq!(
                    &mm, base_mm,
                    "{kernel:?} threads={threads} pool={pool_on}: matmat bits differ"
                );
            }
        }
    }

    pool::set_num_threads(None);
    pool::set_pool_enabled(None);
}
