//! End-to-end learning tests: the full paper protocol (splits → early
//! stopping → refit → predict → AUC) on the synthetic datasets, including
//! the Figure 1 chessboard sanity check that separates linear from
//! nonlinear pairwise kernels.

use gvt_rls::data::chessboard::{ChessboardConfig, Pattern};
use gvt_rls::data::heterodimer::{HeterodimerConfig, ProteinFeature};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::data::metz::MetzConfig;
use gvt_rls::eval::auc;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};

fn quick_cfg() -> RidgeConfig {
    RidgeConfig { max_iters: 80, patience: 8, ..Default::default() }
}

fn train_test_auc(
    data: &gvt_rls::data::PairDataset,
    kernel: PairwiseKernel,
    setting: u8,
    seed: u64,
) -> f64 {
    let split = data.split_setting(setting, 0.25, seed);
    let model =
        PairwiseRidge::fit_early_stopping(&split.train, setting, kernel, &quick_cfg(), seed)
            .unwrap();
    let preds = model.predict(&split.test.pairs).unwrap();
    auc(&preds, &split.test.binary_labels()).unwrap_or(0.5)
}

/// Figure 1: the chessboard (XOR) is unlearnable with the linear pairwise
/// kernel but easy for the Kronecker kernel; the tablecloth (SUM) is easy
/// for both. This is the paper's non-linearity assumption made executable.
#[test]
fn chessboard_separates_linear_from_kronecker() {
    let chess = ChessboardConfig::new(Pattern::Chessboard).generate(3);
    let lin = train_test_auc(&chess, PairwiseKernel::Linear, 1, 5);
    let kron = train_test_auc(&chess, PairwiseKernel::Kronecker, 1, 5);
    assert!(lin < 0.65, "linear kernel should fail on XOR, got AUC {lin}");
    assert!(kron > 0.95, "Kronecker kernel should solve XOR, got AUC {kron}");

    let cloth = ChessboardConfig::new(Pattern::Tablecloth).generate(4);
    let lin2 = train_test_auc(&cloth, PairwiseKernel::Linear, 1, 6);
    assert!(lin2 > 0.95, "linear kernel should solve SUM, got AUC {lin2}");
}

/// Settings ordering (paper §2/§6): Setting 1 is easiest; Setting 4 is
/// hardest. We assert the weak form (S1 ≥ S4 − noise) that holds robustly
/// on the synthetic data.
#[test]
fn setting1_easier_than_setting4() {
    let data = MetzConfig::small().generate(11);
    let s1 = train_test_auc(&data, PairwiseKernel::Kronecker, 1, 7);
    let s4 = train_test_auc(&data, PairwiseKernel::Kronecker, 4, 7);
    assert!(s1 > 0.7, "setting 1 AUC too low: {s1}");
    assert!(s1 + 0.02 >= s4, "setting 1 ({s1}) should not trail setting 4 ({s4})");
}

/// GVT-trained and explicitly-trained models must be the *same* model —
/// "identical except for the calculation of the matrix vector products".
#[test]
fn gvt_and_explicit_training_produce_same_alpha() {
    use gvt_rls::gvt::explicit::ExplicitLinOp;
    let data = MetzConfig::small().generate(12);
    let rows: Vec<usize> = (0..200).collect();
    let small = data.subset(&rows);
    let cfg = RidgeConfig { lambda: 0.1, max_iters: 300, rel_tol: 1e-12, ..Default::default() };
    let gvt_model = PairwiseRidge::fit(&small, PairwiseKernel::Kronecker, &cfg).unwrap();
    let op = ExplicitLinOp::new(
        PairwiseKernel::Kronecker,
        &small.d,
        &small.t,
        &small.pairs,
        &small.pairs,
    );
    let (alpha, _) = PairwiseRidge::fit_with_op(&op, &small.y, &cfg, 300).unwrap();
    let err = gvt_rls::linalg::vecops::max_abs_diff(&gvt_model.alpha, &alpha);
    assert!(err < 1e-6, "alpha mismatch: {err}");
}

/// The paper's observation that nonlinear kernels capture real pairwise
/// signal: on Metz-like data with interactions, Kronecker ≥ Linear.
#[test]
fn kronecker_at_least_matches_linear_on_interaction_data() {
    let cfg = MetzConfig { interaction_strength: 2.0, ..MetzConfig::small() };
    let data = cfg.generate(13);
    let lin = train_test_auc(&data, PairwiseKernel::Linear, 1, 9);
    let kron = train_test_auc(&data, PairwiseKernel::Kronecker, 1, 9);
    assert!(
        kron + 0.03 >= lin,
        "Kronecker ({kron}) should not trail Linear ({lin}) with strong interactions"
    );
}

/// Homogeneous kernels run end-to-end on the heterodimer data.
#[test]
fn homogeneous_kernels_work_on_heterodimer() {
    let data = HeterodimerConfig::small().generate(ProteinFeature::Domain, 14);
    for kernel in [PairwiseKernel::Symmetric, PairwiseKernel::Mlpk] {
        let a = train_test_auc(&data, kernel, 1, 15);
        assert!(a > 0.55, "{kernel:?} AUC {a} barely above chance");
    }
}

/// Kernel filling end-to-end: feature kernel predicts label kernel.
#[test]
fn kernel_filling_learns() {
    let data = KernelFillingConfig::small().generate(48, 1200, 16);
    let a = train_test_auc(&data, PairwiseKernel::Kronecker, 1, 17);
    assert!(a > 0.7, "kernel filling AUC {a}");
}

/// Early stopping history: the optimal iteration must equal the argmax of
/// the validation curve, and the refit model uses it.
#[test]
fn early_stopping_protocol_consistency() {
    let data = MetzConfig::small().generate(18);
    let split = data.split_setting(2, 0.3, 19);
    let model = PairwiseRidge::fit_early_stopping(
        &split.train,
        2,
        PairwiseKernel::Poly2D,
        &quick_cfg(),
        20,
    )
    .unwrap();
    assert!(!model.history.is_empty());
    let best = model
        .history
        .iter()
        .max_by(|a, b| a.validation_auc.partial_cmp(&b.validation_auc).unwrap())
        .unwrap();
    assert_eq!(model.iterations, best.iteration);
}
