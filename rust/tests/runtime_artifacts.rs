//! L3 ↔ L2/L1 integration: the AOT-compiled XLA artifact must compute the
//! same Kronecker mat-vec as the rust-native GVT (f32 vs f64 tolerance).
//!
//! These tests skip (with a loud message) when `make artifacts` hasn't
//! been run — the rust-native path never depends on python.

use gvt_rls::gvt::vec_trick::{gvt_matvec, GvtPolicy};
use gvt_rls::linalg::vecops;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::runtime::{KronExec, Registry};
use gvt_rls::testing::gen;

fn registry_or_skip() -> Option<Registry> {
    match Registry::discover() {
        Some(r) => Some(r),
        None => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_buckets() {
    let Some(reg) = registry_or_skip() else { return };
    assert!(!reg.artifacts().is_empty());
    for a in reg.artifacts() {
        assert!(a.m > 0 && a.q > 0 && a.n > 0);
        assert!(reg.path_of(a).is_file());
    }
    // Smallest bucket covers small problems.
    assert!(reg.pick(16, 16).is_some());
}

#[test]
fn xla_matvec_matches_rust_gvt() {
    let Some(reg) = registry_or_skip() else { return };
    let meta = reg.pick(32, 32).expect("no bucket").clone();
    let exec = KronExec::load(&reg, &meta).expect("compile artifact");
    let mut rng = Xoshiro256::seed_from(100);
    for trial in 0..5 {
        let m = 8 + trial * 5;
        let q = 6 + trial * 4;
        let n = 50 + trial * 30;
        let nbar = 40 + trial * 10;
        let d = gen::psd_kernel(&mut rng, m);
        let t = gen::psd_kernel(&mut rng, q);
        let cols = gen::pair_sample(&mut rng, n, m, q);
        let rows = gen::pair_sample(&mut rng, nbar, m, q);
        let a = dist::normal_vec(&mut rng, n);
        let p_xla = exec.matvec(&d, &t, &rows, &cols, &a).expect("execute");
        let p_rust = gvt_matvec(&d, &t, &rows, &cols, &a, GvtPolicy::Auto);
        let err = vecops::max_abs_diff(&p_xla, &p_rust);
        let scale = p_rust.iter().fold(1.0f64, |m, x| m.max(x.abs()));
        assert!(
            err < 1e-3 * scale,
            "trial {trial}: XLA vs rust err {err} (scale {scale})"
        );
    }
}

#[test]
fn chunking_handles_outputs_larger_than_bucket() {
    let Some(reg) = registry_or_skip() else { return };
    // Pick the smallest bucket and request more output rows than its n.
    let meta = reg
        .artifacts()
        .iter()
        .min_by_key(|a| a.n)
        .unwrap()
        .clone();
    let exec = KronExec::load(&reg, &meta).expect("compile");
    let mut rng = Xoshiro256::seed_from(101);
    let m = 10;
    let q = 10;
    let d = gen::psd_kernel(&mut rng, m);
    let t = gen::psd_kernel(&mut rng, q);
    let n = 60;
    let nbar = meta.n + 37; // forces 2 chunks with a ragged tail
    let cols = gen::pair_sample(&mut rng, n, m, q);
    let rows = gen::pair_sample(&mut rng, nbar, m, q);
    let a = dist::normal_vec(&mut rng, n);
    let p_xla = exec.matvec(&d, &t, &rows, &cols, &a).expect("execute");
    assert_eq!(p_xla.len(), nbar);
    let p_rust = gvt_matvec(&d, &t, &rows, &cols, &a, GvtPolicy::Auto);
    let err = vecops::max_abs_diff(&p_xla, &p_rust);
    assert!(err < 1e-3, "chunked err {err}");
}

#[test]
fn oversize_kernel_is_rejected() {
    let Some(reg) = registry_or_skip() else { return };
    let meta = reg.artifacts().iter().min_by_key(|a| a.m).unwrap().clone();
    let exec = KronExec::load(&reg, &meta).expect("compile");
    let mut rng = Xoshiro256::seed_from(102);
    let m = meta.m + 1; // one too many drugs
    let d = gen::psd_kernel(&mut rng, m);
    let t = gen::psd_kernel(&mut rng, 4);
    let s = gen::pair_sample(&mut rng, 10, m, 4);
    let a = dist::normal_vec(&mut rng, 10);
    assert!(exec.matvec(&d, &t, &s, &s, &a).is_err());
}

#[test]
fn zero_coefficients_give_zero_output() {
    let Some(reg) = registry_or_skip() else { return };
    let meta = reg.pick(8, 8).unwrap().clone();
    let exec = KronExec::load(&reg, &meta).expect("compile");
    let mut rng = Xoshiro256::seed_from(103);
    let d = gen::psd_kernel(&mut rng, 8);
    let t = gen::psd_kernel(&mut rng, 8);
    let s = gen::pair_sample(&mut rng, 20, 8, 8);
    let p = exec.matvec(&d, &t, &s, &s, &vec![0.0; 20]).unwrap();
    assert!(p.iter().all(|&x| x == 0.0));
}
