//! Serving correctness under concurrency: N client threads hammering the
//! micro-batching dispatcher must produce **bit-identical** results to
//! sequential `RidgeModel::predict` — for all 8 pairwise kernels and all
//! four out-of-sample settings of Table 1.
//!
//! Why this can be exact (not a tolerance): the `Predictor` pins the GVT
//! factorization to one concrete mode, stage-1 work depends only on the
//! (fixed) training sample and `α`, and every stage-2 / pooled / misc
//! path computes each output entry by a row-independent operation
//! sequence. Coalescing therefore cannot change a single bit of any
//! response, no matter how requests interleave.
//!
//! The `GVT_RLS_NO_FUSE` ablation is covered by running this whole test
//! binary under both values — scripts/verify.sh executes it with
//! `GVT_RLS_NO_FUSE=1` in addition to the default `cargo test` run (the
//! flag is read once per process, so both paths need their own run).

use gvt_rls::data::PairDataset;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::serve::{BatchConfig, Batcher, Predictor, QueryPair, ServeOptions};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig, RidgeModel};
use gvt_rls::testing::gen;
use std::sync::Arc;
use std::time::Duration;

/// Homogeneous dataset (m == q, shared kernel matrix) so every kernel,
/// including Symmetric/AntiSymmetric/Ranking/MLPK, is applicable.
fn homogeneous_dataset(seed: u64, m: usize, n: usize) -> PairDataset {
    let mut rng = Xoshiro256::seed_from(seed);
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let pairs = gen::homogeneous_sample(&mut rng, n, m);
    let y: Vec<f64> =
        dist::normal_vec(&mut rng, n).iter().map(|v| if *v > 0.0 { 1.0 } else { 0.0 }).collect();
    PairDataset { name: "serve-conc".into(), d: d.clone(), t: d, pairs, y, homogeneous: true }
}

fn heterogeneous_dataset(seed: u64, m: usize, q: usize, n: usize) -> PairDataset {
    let mut rng = Xoshiro256::seed_from(seed);
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let t = Arc::new(gen::psd_kernel(&mut rng, q));
    let pairs = gen::pair_sample(&mut rng, n, m, q);
    let y = dist::normal_vec(&mut rng, n);
    PairDataset { name: "serve-conc-het".into(), d, t, pairs, y, homogeneous: false }
}

/// Build the sequential oracle: the same model, predicted through
/// `RidgeModel::predict` with the predictor's pinned policy.
fn oracle_for(pred: &Predictor, data: &PairDataset) -> RidgeModel {
    let m = pred.model();
    RidgeModel::from_parts(
        m.kernel(),
        data.d.clone(),
        data.t.clone(),
        m.train_pairs().clone(),
        pred.policy(),
        m.alpha.clone(),
        m.lambda,
    )
    .unwrap()
}

/// Hammer the batcher with `threads` clients, each scoring its share of
/// `queries` in small chunks, and assert every reply is bit-identical to
/// the oracle's entry.
fn hammer_and_check(
    pred: Arc<Predictor>,
    queries: &[QueryPair],
    expect: &[f64],
    threads: usize,
    label: &str,
) {
    let batcher = Batcher::start(
        pred,
        BatchConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(300),
            ..Default::default()
        },
    );
    let mut workers = Vec::new();
    for w in 0..threads {
        let handle = batcher.handle();
        // Strided assignment so concurrent batches mix distant pairs.
        let mine: Vec<(usize, QueryPair)> = queries
            .iter()
            .cloned()
            .enumerate()
            .filter(|(i, _)| i % threads == w)
            .collect();
        workers.push(std::thread::spawn(move || {
            let mut flat: Vec<(usize, f64)> = Vec::new();
            for chunk in mine.chunks(3) {
                let pairs: Vec<QueryPair> = chunk.iter().map(|(_, p)| p.clone()).collect();
                let scores = handle.score(pairs).unwrap();
                assert_eq!(scores.len(), chunk.len());
                for ((i, _), s) in chunk.iter().zip(&scores) {
                    flat.push((*i, *s));
                }
            }
            flat
        }));
    }
    for worker in workers {
        for (i, s) in worker.join().unwrap() {
            assert_eq!(
                s.to_bits(),
                expect[i].to_bits(),
                "{label}: pair {i} differs from sequential predict ({s} vs {})",
                expect[i]
            );
        }
    }
    batcher.shutdown();
}

/// The acceptance matrix: all 8 kernels × the four out-of-sample
/// settings, batched server scoring vs sequential `RidgeModel::predict`.
#[test]
fn batched_is_bit_identical_to_sequential_predict() {
    let data = homogeneous_dataset(7, 10, 150);
    let cfg = RidgeConfig { max_iters: 15, ..Default::default() };
    for kernel in PairwiseKernel::ALL {
        for setting in 1u8..=4 {
            let split = data.split_setting(setting, 0.3, 11);
            if split.train.is_empty() || split.test.is_empty() {
                continue;
            }
            let model =
                PairwiseRidge::fit_fixed_iters(&split.train, kernel, &cfg, 15).unwrap();
            let pred =
                Arc::new(Predictor::new(model, None, None, ServeOptions::default()).unwrap());
            let oracle = oracle_for(&pred, &split.train);
            let expect = oracle.predict(&split.test.pairs).unwrap();
            let queries: Vec<QueryPair> = (0..split.test.pairs.len())
                .map(|i| {
                    QueryPair::known(
                        split.test.pairs.drug(i) as u32,
                        split.test.pairs.target(i) as u32,
                    )
                })
                .collect();
            hammer_and_check(
                pred,
                &queries,
                &expect,
                4,
                &format!("{} setting {setting}", kernel.name()),
            );
        }
    }
}

/// Same matrix on a heterogeneous dataset for the kernels that allow it.
#[test]
fn heterogeneous_kernels_bit_identical_under_batching() {
    let data = heterogeneous_dataset(13, 9, 12, 160);
    let cfg = RidgeConfig { max_iters: 15, ..Default::default() };
    for kernel in [
        PairwiseKernel::Linear,
        PairwiseKernel::Poly2D,
        PairwiseKernel::Kronecker,
        PairwiseKernel::Cartesian,
    ] {
        for setting in 1u8..=4 {
            let split = data.split_setting(setting, 0.3, 17);
            if split.train.is_empty() || split.test.is_empty() {
                continue;
            }
            let model =
                PairwiseRidge::fit_fixed_iters(&split.train, kernel, &cfg, 15).unwrap();
            let pred =
                Arc::new(Predictor::new(model, None, None, ServeOptions::default()).unwrap());
            let oracle = oracle_for(&pred, &split.train);
            let expect = oracle.predict(&split.test.pairs).unwrap();
            let queries: Vec<QueryPair> = (0..split.test.pairs.len())
                .map(|i| {
                    QueryPair::known(
                        split.test.pairs.drug(i) as u32,
                        split.test.pairs.target(i) as u32,
                    )
                })
                .collect();
            hammer_and_check(
                pred,
                &queries,
                &expect,
                4,
                &format!("het {} setting {setting}", kernel.name()),
            );
        }
    }
}

/// Direct (non-batcher) `Predictor::score` over arbitrary sub-batches is
/// also bit-identical to one whole-sample predict — the property the
/// dispatcher's correctness rests on, checked without any threading.
#[test]
fn arbitrary_batch_partitions_are_bit_stable() {
    let data = homogeneous_dataset(23, 8, 120);
    let cfg = RidgeConfig { max_iters: 12, ..Default::default() };
    for kernel in [PairwiseKernel::Ranking, PairwiseKernel::Mlpk, PairwiseKernel::Kronecker] {
        let model = PairwiseRidge::fit_fixed_iters(&data, kernel, &cfg, 12).unwrap();
        let pred = Predictor::new(model, None, None, ServeOptions::default()).unwrap();
        let mut rng = Xoshiro256::seed_from(24);
        let test = gen::homogeneous_sample(&mut rng, 41, 8);
        let queries: Vec<QueryPair> = (0..test.len())
            .map(|i| QueryPair::known(test.drug(i) as u32, test.target(i) as u32))
            .collect();
        let whole = pred.score(&queries).unwrap();
        for chunk_size in [1usize, 2, 7, 41] {
            let mut got = Vec::new();
            for chunk in queries.chunks(chunk_size) {
                got.extend(pred.score(chunk).unwrap());
            }
            let bits_equal = whole
                .iter()
                .zip(&got)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(bits_equal, "{kernel:?} chunk_size {chunk_size}");
        }
    }
}
