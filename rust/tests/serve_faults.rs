//! Production-hardening acceptance: the serve subsystem under injected
//! faults and operational stress. Every scenario here drives the public
//! serving surface — [`PredictorSlot`] + [`Batcher`] or a live TCP
//! server — and asserts the robustness contract: **every healthy client
//! gets an in-band answer and the process never aborts**, whatever the
//! fault registry throws at the pipeline.
//!
//! Scenarios (the fault points are armed via
//! `gvt_rls::runtime::fault::set`, same mechanism as `GVT_RLS_FAULT`):
//!
//! * hot-reload under concurrent load is bit-identical (same artifact →
//!   same bits, reload swaps never tear a batch);
//! * a truncated artifact (`artifact_read:truncate`) rejects the reload
//!   and the old model keeps serving, bit-identically;
//! * an overload burst against a saturated admission budget is rejected
//!   in-band with a retry hint, and the budget frees once the stalled
//!   batch completes (`batcher_dispatch:stall`);
//! * a scoring panic (`batcher_dispatch:panic`) is answered in-band and
//!   the dispatcher keeps serving the very next request;
//! * a TCP client can trigger `{"cmd": "reload"}` mid-stream: responses
//!   before and after render byte-identically, a bad reload path errors
//!   in-band, and the robust counters surface in `{"cmd": "stats"}`.
//!
//! The fault registry is process-global, so every test serializes on
//! [`FAULT_LOCK`] (artifact loading also passes a fault point — even the
//! tests that arm nothing must hold the lock while building predictors).

use gvt_rls::data::PairDataset;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::runtime::fault;
use gvt_rls::serve::{
    serve_on, BatchConfig, Batcher, PredictorSlot, QueryPair, ScoreFailure, ServeConfig,
    ServeOptions,
};
use gvt_rls::solvers::persist::{save_model_v2, EmbedV2};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use gvt_rls::testing::gen;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// The fault registry is one per process: tests that touch it (or load
/// artifacts, which pass the `artifact_read` point) must not interleave.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn fault_guard() -> MutexGuard<'static, ()> {
    // A poisoned lock only means another test failed; the registry is
    // still usable (each test clears it on entry).
    FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Train a small heterogeneous Kronecker model, persist it as a
/// self-contained v2 artifact, and wrap a freshly loaded predictor in a
/// [`PredictorSlot`] — the same seam the server uses.
fn toy_slot(seed: u64, tag: &str) -> (Arc<PredictorSlot>, PathBuf) {
    let mut rng = Xoshiro256::seed_from(seed);
    let d = Arc::new(gen::psd_kernel(&mut rng, 6));
    let t = Arc::new(gen::psd_kernel(&mut rng, 7));
    let pairs = gen::pair_sample(&mut rng, 30, 6, 7);
    let y = dist::normal_vec(&mut rng, 30);
    let data = PairDataset { name: "faults".into(), d, t, pairs, y, homogeneous: false };
    let cfg = RidgeConfig { max_iters: 15, ..Default::default() };
    let model =
        PairwiseRidge::fit_fixed_iters(&data, PairwiseKernel::Kronecker, &cfg, 15).unwrap();
    let path =
        std::env::temp_dir().join(format!("gvt_faults_{tag}_{}.txt", std::process::id()));
    save_model_v2(&model, &path, &EmbedV2 { matrices: true, ..Default::default() }).unwrap();
    let pred = Arc::new(
        gvt_rls::serve::Predictor::from_file(&path, ServeOptions::default()).unwrap(),
    );
    (PredictorSlot::new(pred, ServeOptions::default()), path)
}

/// Hot-reload while four client threads hammer the dispatcher: every
/// reply must stay bit-identical to the pre-reload scores (the predictor
/// pins its factorization from the artifact alone), and no request may
/// error or hang across the swaps.
#[test]
fn reload_under_load_is_bit_identical() {
    let _g = fault_guard();
    fault::clear();
    let (slot, path) = toy_slot(71, "reload_load");
    let queries: Vec<QueryPair> =
        (0..6u32).flat_map(|d| (0..7u32).map(move |t| QueryPair::known(d, t))).collect();
    let expect = slot.current().score(&queries).unwrap();

    let batcher = Batcher::start_with_slot(
        slot.clone(),
        BatchConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        },
    );
    let mut workers = Vec::new();
    for w in 0..4usize {
        let handle = batcher.handle();
        let queries = queries.clone();
        let expect = expect.clone();
        workers.push(std::thread::spawn(move || {
            for round in 0..40usize {
                let i = (w * 13 + round * 5) % queries.len();
                let j = (i + 3).min(queries.len());
                let scores = handle.score(queries[i..j].to_vec()).unwrap();
                for (s, e) in scores.iter().zip(&expect[i..j]) {
                    assert_eq!(
                        s.to_bits(),
                        e.to_bits(),
                        "reply diverged from the sequential oracle during a reload"
                    );
                }
            }
        }));
    }
    // Swap the model repeatedly while the clients run. Same artifact, so
    // correctness is bit-identity; the point is that no swap tears a
    // batch or drops a request.
    for _ in 0..6 {
        slot.reload_from_path(&path).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    for worker in workers {
        worker.join().unwrap();
    }
    assert!(slot.robust.snapshot().reloads_ok >= 6);
    batcher.shutdown();
    let _ = std::fs::remove_file(&path);
}

/// A reload that reads a truncated artifact (injected at the
/// `artifact_read` point) must be rejected with a contextual error while
/// the previous model keeps serving, bit-identically.
#[test]
fn truncated_artifact_reload_keeps_old_model() {
    let _g = fault_guard();
    fault::clear();
    let (slot, path) = toy_slot(72, "trunc");
    let q = [QueryPair::known(2, 4)];
    let before = slot.current().score(&q).unwrap();

    fault::set("artifact_read:truncate:1").unwrap();
    let err = slot.reload_from_path(&path).unwrap_err();
    fault::clear();
    let msg = format!("{err:#}");
    assert!(msg.contains("reload rejected"), "{msg}");

    let after = slot.current().score(&q).unwrap();
    assert_eq!(
        before[0].to_bits(),
        after[0].to_bits(),
        "old model must keep serving unchanged after a failed reload"
    );
    let snap = slot.robust.snapshot();
    assert_eq!(snap.reloads_failed, 1);
    assert_eq!(snap.reloads_ok, 0);
    let _ = std::fs::remove_file(&path);
}

/// Overload burst and recovery: with a 1-pair admission budget held by a
/// stalled batch (`batcher_dispatch:stall`), a concurrent request is
/// rejected in-band with a retry hint; once the stalled batch completes
/// the budget frees and requests are admitted again.
#[test]
fn overload_burst_rejected_in_band_and_recovers() {
    let _g = fault_guard();
    fault::clear();
    let (slot, path) = toy_slot(73, "overload");
    let expect = slot.current().score(&[QueryPair::known(1, 2)]).unwrap();

    let batcher = Batcher::start_with_slot(
        slot.clone(),
        BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            max_inflight: 1,
            ..Default::default()
        },
    );
    fault::set("batcher_dispatch:stall:1").unwrap();
    let h1 = batcher.handle();
    let stalled =
        std::thread::spawn(move || h1.submit(vec![QueryPair::known(1, 2)], None));
    // The budget is reserved at submit time and released only when the
    // job is answered, and the stall holds the dispatch for ~400 ms —
    // so after this sleep the rejection below cannot race.
    std::thread::sleep(Duration::from_millis(50));

    let handle = batcher.handle();
    match handle.submit(vec![QueryPair::known(0, 0)], None) {
        Err(ScoreFailure::Overloaded { retry_after_us }) => {
            assert!(retry_after_us >= 100, "retry hint must be at least 100us");
        }
        other => panic!("expected an overload rejection, got {other:?}"),
    }

    // The stalled request itself is still answered correctly — a stall
    // delays, it does not corrupt.
    let first = stalled.join().unwrap().expect("stalled request must still be answered");
    assert_eq!(first[0].to_bits(), expect[0].to_bits());

    // Recovery: the budget frees once the stalled batch is answered.
    let mut recovered = None;
    for _ in 0..200 {
        match handle.submit(vec![QueryPair::known(1, 2)], None) {
            Ok(scores) => {
                recovered = Some(scores);
                break;
            }
            Err(ScoreFailure::Overloaded { .. }) => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(ScoreFailure::Failed(msg)) => panic!("unexpected failure: {msg}"),
        }
    }
    let recovered = recovered.expect("admission budget never freed after the stall");
    assert_eq!(recovered[0].to_bits(), expect[0].to_bits());
    assert!(slot.robust.snapshot().overload_rejected >= 1);

    fault::clear();
    drop(handle);
    batcher.shutdown();
    let _ = std::fs::remove_file(&path);
}

/// An injected panic in the scoring pass is answered in-band and the
/// dispatcher survives to serve the very next request with correct bits.
#[test]
fn dispatcher_panic_is_answered_in_band_and_dispatcher_survives() {
    let _g = fault_guard();
    fault::clear();
    let (slot, path) = toy_slot(74, "panic");
    let q = vec![QueryPair::known(3, 5)];
    let expect = slot.current().score(&q).unwrap();

    let batcher = Batcher::start_with_slot(
        slot.clone(),
        BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    );
    fault::set("batcher_dispatch:panic:1").unwrap();
    let handle = batcher.handle();
    match handle.submit(q.clone(), None) {
        Err(ScoreFailure::Failed(msg)) => {
            assert!(msg.contains("scoring panicked"), "{msg}");
        }
        other => panic!("expected an in-band panic error, got {other:?}"),
    }
    fault::clear();

    let scores =
        handle.submit(q, None).expect("dispatcher must keep serving after a panic");
    assert_eq!(scores[0].to_bits(), expect[0].to_bits());
    assert_eq!(slot.robust.snapshot().dispatcher_panics, 1);

    drop(handle);
    batcher.shutdown();
    let _ = std::fs::remove_file(&path);
}

fn roundtrip(w: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(w, "{req}").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(!line.is_empty(), "server closed the connection on: {req}");
    line.trim_end().to_string()
}

/// Full TCP round trip with a mid-stream hot-reload: scores before and
/// after `{"cmd": "reload"}` render byte-identically (same artifact →
/// same bits → same 17-significant-digit rendering), a bad reload path
/// is an in-band error that leaves the old model serving, and the
/// robust counters show up in `{"cmd": "stats"}`.
#[test]
fn tcp_reload_mid_stream_is_bit_identical_and_in_band() {
    let _g = fault_guard();
    fault::clear();
    let (slot, path) = toy_slot(75, "tcp");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig {
        batch: BatchConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
        model_path: Some(path.clone()),
        ..Default::default()
    };
    let pred = slot.current();
    let server = std::thread::spawn(move || serve_on(listener, pred, cfg));

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    let score_req = r#"{"id": 1, "pairs": [[0, 3], [5, 1], [2, 6]]}"#;
    let before = roundtrip(&mut w, &mut r, score_req);
    assert!(before.contains("\"scores\""), "{before}");

    // Reload from the server's configured artifact (no explicit path).
    let reload_ok = roundtrip(&mut w, &mut r, r#"{"id": 2, "cmd": "reload"}"#);
    assert!(reload_ok.contains("\"ok\": true"), "{reload_ok}");
    let after = roundtrip(&mut w, &mut r, score_req);
    assert_eq!(before, after, "same artifact after reload must render identically");

    // A bad reload path errors in-band and changes nothing.
    let bad = roundtrip(
        &mut w,
        &mut r,
        r#"{"id": 3, "cmd": "reload", "path": "/no/such/gvt_artifact.txt"}"#,
    );
    assert!(bad.contains("\"error\""), "{bad}");
    assert!(bad.contains("reload rejected"), "{bad}");
    let still = roundtrip(&mut w, &mut r, score_req);
    assert_eq!(before, still, "a failed reload must leave the old model serving");

    let stats = roundtrip(&mut w, &mut r, r#"{"id": 4, "cmd": "stats"}"#);
    assert!(stats.contains("\"reloads_ok\": 1"), "{stats}");
    assert!(stats.contains("\"reloads_failed\": 1"), "{stats}");

    let bye = roundtrip(&mut w, &mut r, r#"{"id": 5, "cmd": "shutdown"}"#);
    assert!(bye.contains("\"ok\": true"), "{bye}");
    drop(r);
    drop(w);
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_file(&path);
}
