//! Stochastic-solver acceptance: mini-batched SGD reaches the exact
//! (CG) solution of `(K + λI)α = y` on every pairwise kernel across the
//! four split settings, runs are bit-reproducible from their seed, and
//! an SGD-trained v2 artifact serves bit-stably through the `gvt-rls
//! predict` machinery.
//!
//! Documented tolerance (see rust/DESIGN.md §Stochastic-Solver): with
//! the monitor stopping at relative gradient norm `tol`, the solution
//! error is bounded by `‖α − α*‖ ≤ tol·‖y‖ / λ_min(K + λI) ≤
//! tol·‖y‖/λ`; the assertions below use `tol = 1e-7` with λ = 1.5 and
//! check α and predictions to 1e-4.

use gvt_rls::data::PairDataset;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::serve::{Predictor, QueryPair, ServeOptions};
use gvt_rls::solvers::cg::{cg, CgOptions};
use gvt_rls::solvers::linear_op::ShiftedOp;
use gvt_rls::solvers::persist::{save_model_v2, EmbedV2};
use gvt_rls::solvers::ridge::RidgeModel;
use gvt_rls::solvers::{SgdConfig, SgdTrainer};
use gvt_rls::testing::gen;
use std::ops::ControlFlow;
use std::sync::Arc;

/// Homogeneous toy dataset with a **normalized** object kernel
/// (`k_ij / √(k_ii k_jj)`, unit diagonal) so the pairwise operator's
/// conditioning stays moderate and the convergence loop below is fast.
fn homogeneous_toy(seed: u64, n: usize, m: usize) -> PairDataset {
    let mut rng = Xoshiro256::seed_from(seed);
    let raw = gen::psd_kernel(&mut rng, m);
    let mut d = raw.clone();
    for i in 0..m {
        for j in 0..m {
            d[(i, j)] = raw[(i, j)] / (raw[(i, i)] * raw[(j, j)]).sqrt();
        }
    }
    let d = Arc::new(d);
    let pairs = gen::homogeneous_sample(&mut rng, n, m);
    let y = dist::normal_vec(&mut rng, n);
    PairDataset { name: "sgd-conv".into(), d: d.clone(), t: d, pairs, y, homogeneous: true }
}

/// Exact dual coefficients via CG on the same training operator.
fn cg_alpha(train: &PairDataset, kernel: PairwiseKernel, lambda: f64) -> Vec<f64> {
    let op = PairwiseLinOp::new(
        kernel,
        train.d.clone(),
        train.t.clone(),
        train.pairs.clone(),
        train.pairs.clone(),
        GvtPolicy::Auto,
    )
    .unwrap();
    let shifted = ShiftedOp::new(&op, lambda);
    let out = cg(
        &shifted,
        &train.y,
        None,
        &CgOptions { max_iters: 20_000, rel_tol: 1e-12 },
        |_, _, _| ControlFlow::Continue(()),
    )
    .unwrap();
    assert!(out.converged, "CG oracle failed to converge");
    out.x
}

/// All 8 kernels, cycling the four split settings (kernel `i` trains on
/// the setting-`(i mod 4)+1` training split): SGD α matches the exact CG
/// solution and so do held-out predictions.
#[test]
fn sgd_matches_cg_on_all_kernels_across_settings() {
    let data = homogeneous_toy(500, 90, 10);
    let lambda = 1.5;
    for (i, kernel) in PairwiseKernel::ALL.into_iter().enumerate() {
        let setting = (i % 4) as u8 + 1;
        let split = data.split_setting(setting, 0.25, 41);
        assert!(
            split.train.len() >= 8 && !split.test.is_empty(),
            "degenerate setting-{setting} split in the fixture"
        );
        let cfg = SgdConfig {
            batch_size: 16,
            epochs: 30_000,
            tol: 1e-7,
            check_every: 25,
            patience: 600,
            ..Default::default()
        };
        let trainer = SgdTrainer::new(&split.train, kernel, cfg).unwrap();
        let run = trainer.fit(lambda, 13).unwrap();
        assert!(
            run.converged,
            "{kernel:?} setting {setting}: rel_grad {} after {} epochs",
            run.rel_grad,
            run.epochs
        );
        let exact = cg_alpha(&split.train, kernel, lambda);
        for (a, o) in run.alpha.iter().zip(&exact) {
            assert!(
                (a - o).abs() < 1e-4,
                "{kernel:?} setting {setting}: alpha {a} vs exact {o}"
            );
        }
        // Held-out predictions agree too (documented tolerance). The
        // model is assembled from the run's α — not refit — so this
        // costs one prediction pass per side.
        let sgd_model = RidgeModel::from_parts(
            kernel,
            split.train.d.clone(),
            split.train.t.clone(),
            split.train.pairs.clone(),
            trainer.policy(),
            run.alpha.clone(),
            lambda,
        )
        .unwrap();
        let exact_model = RidgeModel::from_parts(
            kernel,
            split.train.d.clone(),
            split.train.t.clone(),
            split.train.pairs.clone(),
            trainer.policy(),
            exact,
            lambda,
        )
        .unwrap();
        let p_sgd = sgd_model.predict(&split.test.pairs).unwrap();
        let p_exact = exact_model.predict(&split.test.pairs).unwrap();
        for (a, b) in p_sgd.iter().zip(&p_exact) {
            assert!(
                (a - b).abs() < 1e-4,
                "{kernel:?} setting {setting}: prediction {a} vs {b}"
            );
        }
    }
}

/// Fixed seed → bit-identical trajectory; different seed → different
/// epoch shuffles (stopped mid-run so trajectories are distinguishable).
#[test]
fn sgd_is_deterministic_under_a_fixed_seed() {
    let data = homogeneous_toy(501, 60, 8);
    let cfg = SgdConfig {
        batch_size: 8,
        epochs: 9,
        tol: 0.0,
        ..Default::default()
    };
    let trainer = SgdTrainer::new(&data, PairwiseKernel::Poly2D, cfg).unwrap();
    let a = trainer.fit(0.8, 7).unwrap();
    let b = trainer.fit(0.8, 7).unwrap();
    assert_eq!(a.steps, b.steps);
    assert_eq!(
        a.alpha.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        b.alpha.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "same seed must reproduce α bit-for-bit"
    );
    let c = trainer.fit(0.8, 8).unwrap();
    assert_ne!(a.alpha, c.alpha, "different seeds must shuffle differently");
}

/// An SGD-trained model saved as a v2 artifact round-trips through the
/// serving/predict machinery bit-stably: α survives bit-for-bit, and
/// the Predictor (the engine behind `gvt-rls predict`) scores pairs
/// bit-identically to in-process `RidgeModel::predict` — including the
/// exact `{:.17e}` wire rendering.
#[test]
fn sgd_v2_artifact_roundtrips_bitstably_through_predict() {
    let data = homogeneous_toy(502, 70, 9);
    let cfg = SgdConfig {
        batch_size: 16,
        epochs: 400,
        tol: 1e-5,
        check_every: 10,
        ..Default::default()
    };
    let trainer = SgdTrainer::new(&data, PairwiseKernel::Kronecker, cfg).unwrap();
    let model = trainer.fit_model(0.5, 3).unwrap();
    let alpha_bits: Vec<u64> = model.alpha.iter().map(|x| x.to_bits()).collect();

    let path = std::env::temp_dir().join(format!("gvt_sgd_roundtrip_{}.txt", std::process::id()));
    save_model_v2(&model, &path, &EmbedV2 { matrices: true, ..Default::default() }).unwrap();
    let pred = Predictor::from_file(&path, ServeOptions::default()).unwrap();
    let _ = std::fs::remove_file(&path);

    // α round-trips bit-for-bit through the artifact.
    let loaded_bits: Vec<u64> = pred.model().alpha.iter().map(|x| x.to_bits()).collect();
    assert_eq!(alpha_bits, loaded_bits);

    // Scores through the predict path are bit-identical to the model's.
    let mut rng = Xoshiro256::seed_from(503);
    let test = gen::homogeneous_sample(&mut rng, 23, 9);
    let queries: Vec<QueryPair> = (0..test.len())
        .map(|i| QueryPair::known(test.drug(i) as u32, test.target(i) as u32))
        .collect();
    let offline = model.predict(&test).unwrap();
    let served = pred.score(&queries).unwrap();
    assert_eq!(
        offline.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        served.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "predict path must be bit-identical to RidgeModel::predict"
    );
    for (a, b) in offline.iter().zip(&served) {
        assert_eq!(
            gvt_rls::serve::protocol::fmt_score(*a),
            gvt_rls::serve::protocol::fmt_score(*b)
        );
    }
}
