//! Property tests on the solver stack: MINRES/CG vs the Cholesky oracle,
//! Nyström exactness at full rank, and the GVT-powered ridge vs the
//! closed-form solution.

use gvt_rls::data::PairDataset;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::linalg::chol::{solve_regularized, Cholesky};
use gvt_rls::rng::{dist, Rng, Xoshiro256};
use gvt_rls::solvers::cg::{cg, CgOptions};
use gvt_rls::solvers::linear_op::DenseOp;
use gvt_rls::solvers::minres::{minres, MinresOptions};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use gvt_rls::testing::{gen, property, Prop};
use std::ops::ControlFlow;
use std::sync::Arc;

fn cont(_: usize, _: &[f64], _: f64) -> ControlFlow<()> {
    ControlFlow::Continue(())
}

#[test]
fn minres_matches_cholesky_on_random_spd() {
    property("minres == cholesky", 20, |rng, size| {
        let n = 5 + 2 * size;
        let mut a = gen::psd_kernel(rng, n);
        for i in 0..n {
            a[(i, i)] += 0.2;
        }
        let b = dist::normal_vec(rng, n);
        let oracle = Cholesky::factor(&a).unwrap().solve(&b);
        let out = minres(
            &DenseOp::new(a),
            &b,
            &MinresOptions { max_iters: 50 * n, rel_tol: 1e-12 },
            cont,
        )
        .unwrap();
        Prop::all_close(&out.x, &oracle, 1e-5, "minres")
    });
}

#[test]
fn cg_and_minres_agree_on_spd() {
    property("cg == minres", 16, |rng, size| {
        let n = 5 + 2 * size;
        let mut a = gen::psd_kernel(rng, n);
        for i in 0..n {
            a[(i, i)] += 0.5;
        }
        let b = dist::normal_vec(rng, n);
        let m_out = minres(
            &DenseOp::new(a.clone()),
            &b,
            &MinresOptions { max_iters: 50 * n, rel_tol: 1e-12 },
            cont,
        )
        .unwrap();
        let c_out = cg(
            &DenseOp::new(a),
            &b,
            None,
            &CgOptions { max_iters: 50 * n, rel_tol: 1e-12 },
            cont,
        )
        .unwrap();
        Prop::all_close(&m_out.x, &c_out.x, 1e-5, "cg vs minres")
    });
}

#[test]
fn minres_residual_is_monotone_nonincreasing() {
    // MINRES minimizes the residual over growing Krylov spaces, so the
    // residual-norm estimate must never increase.
    property("minres residual monotone", 12, |rng, size| {
        let n = 6 + 2 * size;
        let a = gen::psd_kernel(rng, n);
        let b = dist::normal_vec(rng, n);
        let mut last = f64::INFINITY;
        let mut ok = true;
        minres(
            &DenseOp::new(a),
            &b,
            &MinresOptions { max_iters: 3 * n, rel_tol: 1e-14 },
            |_, _, res| {
                if res > last + 1e-9 {
                    ok = false;
                    return ControlFlow::Break(());
                }
                last = res;
                ControlFlow::Continue(())
            },
        )
        .unwrap();
        Prop::check(ok, || "residual increased".into())
    });
}

#[test]
fn ridge_gvt_matches_closed_form_all_kernels() {
    property("ridge GVT == closed form", 6, |rng, size| {
        let m = 5 + size / 2;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let n = 20 + 4 * size;
        let pairs = gen::homogeneous_sample(rng, n, m);
        let y = dist::normal_vec(rng, n);
        let data = PairDataset {
            name: "p".into(),
            d: d.clone(),
            t: d.clone(),
            pairs,
            y,
            homogeneous: true,
        };
        let lambda = 1.0; // strong regularization keeps the system well-posed
        let cfg = RidgeConfig {
            lambda,
            max_iters: 4000,
            rel_tol: 1e-13,
            ..Default::default()
        };
        for kernel in [
            PairwiseKernel::Kronecker,
            PairwiseKernel::Symmetric,
            PairwiseKernel::Mlpk,
        ] {
            let model = PairwiseRidge::fit(&data, kernel, &cfg).unwrap();
            let k = gvt_rls::gvt::explicit::explicit_matrix(
                kernel,
                &data.d,
                &data.t,
                &data.pairs,
                &data.pairs,
            );
            let oracle = solve_regularized(&k, lambda, &data.y).unwrap();
            if let Prop::Fail(msg) =
                Prop::all_close(&model.alpha, &oracle, 1e-4, kernel.name())
            {
                return Prop::Fail(msg);
            }
        }
        Prop::Pass
    });
}

#[test]
fn nystrom_with_all_centers_matches_ridge_solution() {
    use gvt_rls::solvers::nystrom::{NystromConfig, NystromModel};
    property("full-rank Nyström == ridge", 4, |rng, size| {
        let m = 5 + size / 2;
        let d = Arc::new(gen::psd_kernel(rng, m));
        let n = 30 + 2 * size;
        let pairs = gen::homogeneous_sample(rng, n, m);
        let y = dist::normal_vec(rng, n);
        let data = PairDataset {
            name: "ny".into(),
            d: d.clone(),
            t: d.clone(),
            pairs: pairs.clone(),
            y,
            homogeneous: true,
        };
        let lambda = 1e-2;
        let ny = NystromModel::fit(
            &data,
            PairwiseKernel::Kronecker,
            &NystromConfig {
                num_centers: n,
                lambda,
                max_iters: 6000,
                rel_tol: 1e-13,
                seed: rng.next_u64(),
                ..Default::default()
            },
        )
        .unwrap();
        // Falkon objective ⇒ ridge with λ_ridge = λ·n.
        let cf = gvt_rls::solvers::closed_form::ClosedFormModel::fit(
            &data,
            PairwiseKernel::Kronecker,
            lambda * n as f64,
        )
        .unwrap();
        let test = gen::homogeneous_sample(rng, 15, m);
        let p1 = ny.predict(&test);
        let p2 = cf.predict(&test);
        Prop::all_close(&p1, &p2, 1e-3, "nystrom vs closed form")
    });
}

#[test]
fn more_nystrom_centers_never_hurt_much() {
    use gvt_rls::solvers::nystrom::{NystromConfig, NystromModel};
    // Weak monotonicity: doubling centers shouldn't make training RMSE
    // dramatically worse (allows small solver noise).
    let mut rng = Xoshiro256::seed_from(200);
    let m = 9;
    let d = Arc::new(gen::psd_kernel(&mut rng, m));
    let n = 120;
    let pairs = gen::homogeneous_sample(&mut rng, n, m);
    let y = dist::normal_vec(&mut rng, n);
    let data =
        PairDataset { name: "nyc".into(), d: d.clone(), t: d, pairs, y, homogeneous: true };
    let mut rmses = Vec::new();
    for nc in [15, 60, 120] {
        let model = NystromModel::fit(
            &data,
            PairwiseKernel::Kronecker,
            &NystromConfig {
                num_centers: nc,
                lambda: 1e-6,
                max_iters: 3000,
                rel_tol: 1e-12,
                seed: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let p = model.predict(&data.pairs);
        rmses.push(gvt_rls::eval::rmse(&p, &data.y));
    }
    assert!(
        rmses[2] <= rmses[0] * 1.05 + 1e-9,
        "train RMSE should improve with centers: {rmses:?}"
    );
}
