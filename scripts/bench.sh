#!/usr/bin/env bash
# Perf-trajectory seeding: run the per-kernel GVT mat-vec bench
# (n ∈ {4k, 16k}, all 8 kernels, fused + unfused ablation rows) into
# BENCH_gvt.json, the serving bench (micro-batched vs per-request
# scoring, batch sizes {1, 8, 64, 256}, p50/p99 latency) into
# BENCH_serve.json, and the stochastic-solver bench (exact CG vs
# mini-batched SGD time-to-ε, n ∈ {16k, 64k}, all 8 kernels) into
# BENCH_sgd.json, the execution-runtime ablation (persistent pool
# vs scoped spawn: region dispatch, mat-vec latency at n ∈ {4k, 16k,
# 64k}, per-iteration MINRES overhead) into BENCH_pool.json, the
# complete-grid eigen shortcut vs CG λ-grid comparison (m = q ∈ {64,
# 128}, 8 λ values, plus the exact-LOOCV pass) into BENCH_eigen.json,
# and the dense micro-kernel ablation (register-blocked tiles vs scalar
# chunk bodies: GEMV, GEMM, stage-1+2 mat-mat at n ∈ {4k, 16k, 64k},
# GFLOP/s column) into BENCH_microkernel.json — all at the repo root so
# future PRs can prove speedups against recorded numbers.
#
# Usage: scripts/bench.sh            # full sizes (~minutes)
#        GVT_RLS_BENCH_QUICK=1 scripts/bench.sh   # small sizes, fast
set -euo pipefail

cd "$(dirname "$0")/.."

# Quick/smoke runs use reduced problem sizes — keep them away from the
# canonical JSON files so they can't clobber the full-size
# perf-trajectory numbers.
if [[ -n "${GVT_RLS_BENCH_QUICK:-}" || -n "${GVT_BENCH_SMOKE:-}" ]]; then
  gvt_json="$PWD/BENCH_gvt_quick.json"
  serve_json="$PWD/BENCH_serve_quick.json"
  sgd_json="$PWD/BENCH_sgd_quick.json"
  pool_json="$PWD/BENCH_pool_quick.json"
  eigen_json="$PWD/BENCH_eigen_quick.json"
  mk_json="$PWD/BENCH_microkernel_quick.json"
else
  gvt_json="$PWD/BENCH_gvt.json"
  serve_json="$PWD/BENCH_serve.json"
  sgd_json="$PWD/BENCH_sgd.json"
  pool_json="$PWD/BENCH_pool.json"
  eigen_json="$PWD/BENCH_eigen.json"
  mk_json="$PWD/BENCH_microkernel.json"
fi

echo "== bench_pairwise_kernels → ${gvt_json} =="
GVT_RLS_BENCH_JSON="${GVT_RLS_BENCH_JSON:-$gvt_json}" \
  cargo bench --offline --bench bench_pairwise_kernels

echo "== bench_serve → ${serve_json} =="
GVT_RLS_BENCH_JSON="$serve_json" \
  cargo bench --offline --bench bench_serve

echo "== bench_sgd → ${sgd_json} =="
GVT_RLS_BENCH_JSON="$sgd_json" \
  cargo bench --offline --bench bench_sgd

echo "== bench_pool → ${pool_json} =="
GVT_RLS_BENCH_JSON="$pool_json" \
  cargo bench --offline --bench bench_pool

echo "== bench_eigen → ${eigen_json} =="
GVT_RLS_BENCH_JSON="$eigen_json" \
  cargo bench --offline --bench bench_eigen

echo "== bench_microkernel → ${mk_json} =="
GVT_RLS_BENCH_JSON="$mk_json" \
  cargo bench --offline --bench bench_microkernel

echo "bench.sh: wrote ${GVT_RLS_BENCH_JSON:-$gvt_json}, ${serve_json}, ${sgd_json}, ${pool_json}, ${eigen_json} and ${mk_json}"
