#!/usr/bin/env bash
# Perf-trajectory seeding: run the per-kernel GVT mat-vec bench
# (n ∈ {4k, 16k}, all 8 kernels, fused + unfused ablation rows) and write
# the results to BENCH_gvt.json at the repo root so future PRs can prove
# speedups against recorded numbers.
#
# Usage: scripts/bench.sh            # full sizes (~minutes)
#        GVT_RLS_BENCH_QUICK=1 scripts/bench.sh   # small sizes, fast
set -euo pipefail

cd "$(dirname "$0")/.."

# Quick/smoke runs use reduced problem sizes — keep them away from the
# canonical BENCH_gvt.json so they can't clobber the full-size
# perf-trajectory numbers.
if [[ -n "${GVT_RLS_BENCH_QUICK:-}" || -n "${GVT_BENCH_SMOKE:-}" ]]; then
  default_json="$PWD/BENCH_gvt_quick.json"
else
  default_json="$PWD/BENCH_gvt.json"
fi
export GVT_RLS_BENCH_JSON="${GVT_RLS_BENCH_JSON:-$default_json}"

echo "== bench_pairwise_kernels → ${GVT_RLS_BENCH_JSON} =="
cargo bench --offline --bench bench_pairwise_kernels

echo "bench.sh: wrote ${GVT_RLS_BENCH_JSON}"
