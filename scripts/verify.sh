#!/usr/bin/env bash
# Tier-1 verification for the gvt_rls workspace, plus the bench/example
# targets that `cargo build`/`cargo test` alone would let rot.
#
# Usage: scripts/verify.sh   (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release --offline =="
cargo build --release --offline

echo "== tier-1: cargo test -q =="
cargo test -q --offline

echo "== benches + examples compile (kept in the workspace) =="
cargo build --offline --benches --examples

echo "verify.sh: OK"
