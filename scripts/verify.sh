#!/usr/bin/env bash
# Tier-1 verification for the gvt_rls workspace, plus the bench/example
# targets that `cargo build`/`cargo test` alone would let rot.
#
# Usage: scripts/verify.sh   (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release --offline =="
cargo build --release --offline

echo "== tier-1: cargo test -q =="
cargo test -q --offline

echo "== benches + examples compile (kept in the workspace) =="
cargo build --offline --benches --examples

echo "== benches execute (smoke mode: 1 warmup + 1 iter, tiny sizes) =="
# GVT_BENCH_SMOKE=1 makes every harness = false bench run a minimal
# configuration (see rust/src/bench/mod.rs) so bench code is executed —
# not just compiled — on every verify and cannot bit-rot silently. The
# list is derived from rust/benches/*.rs so new benches are picked up
# automatically.
for bench_file in rust/benches/*.rs; do
  bench="$(basename "$bench_file" .rs)"
  echo "-- $bench (smoke)"
  GVT_BENCH_SMOKE=1 cargo bench --offline --bench "$bench" >/dev/null
done

echo "verify.sh: OK"
