#!/usr/bin/env bash
# Tier-1 verification for the gvt_rls workspace, plus the bench/example
# targets that `cargo build`/`cargo test` alone would let rot.
#
# Usage: scripts/verify.sh   (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release --offline =="
cargo build --release --offline

echo "== tier-1: gvt-lint (source-level contracts: determinism / alloc-free / unsafe audit / env registry / panic surface / clock monopoly) =="
# Fails on any finding; tests/lint_clean.rs runs the same pass under
# cargo test, this invocation gates the CLI surface and leaves a
# machine-readable dump next to the build artifacts.
target/release/gvt-rls lint
target/release/gvt-rls lint --json > target/lint.json

echo "== tier-1: cargo test -q =="
cargo test -q --offline

echo "== runtime ablations: scoped-spawn fallback + single-thread + scalar micro-kernels =="
# Cross-check the execution runtime's ablation axes over the whole
# tier-1 suite: GVT_RLS_POOL=0 retires the persistent pool (pre-pool
# scoped spawning), GVT_RLS_THREADS=1 forces every parallel region
# inline, and GVT_RLS_MICROKERNEL=0 swaps the register-blocked tile
# kernels for the scalar chunk bodies. The determinism contract (rows as
# the unit of work, fixed per-row reduction order) makes all four
# configurations bit-identical — tests/pool_determinism.rs and
# tests/microkernel_equiv.rs pin that directly; these sweeps prove
# nothing else depends on the runtime.
GVT_RLS_POOL=0 cargo test -q --offline
GVT_RLS_THREADS=1 cargo test -q --offline
GVT_RLS_MICROKERNEL=0 cargo test -q --offline

echo "== eigen lane: oracle/eigh/nystrom suites under both runtime ablations =="
# The full-suite sweeps above already include these, but the eigen
# shortcut's determinism story (serial Jacobi + pooled GEMMs + serial
# scatter/gather) is exactly what the two ablations stress — run the
# brute-force LOOCV oracle and the linalg/nystrom property suites
# explicitly so a regression names itself.
GVT_RLS_POOL=0 cargo test -q --offline --test eigen_oracle
GVT_RLS_POOL=0 cargo test -q --offline --lib -- linalg::eigh solvers::nystrom solvers::complete
GVT_RLS_THREADS=1 cargo test -q --offline --test eigen_oracle
GVT_RLS_THREADS=1 cargo test -q --offline --lib -- linalg::eigh solvers::nystrom solvers::complete

echo "== benches + examples compile (kept in the workspace) =="
cargo build --offline --benches --examples

echo "== rustdoc builds (public-API docs cannot rot) =="
# -D warnings: broken intra-doc links are rustdoc *warnings* and would
# otherwise exit 0 — deny them so the doc gate actually gates.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline

echo "== serve: bit-identity under the unfused ablation (GVT_RLS_NO_FUSE=1) =="
# The flag is read once per process, so the fused run above and this
# unfused run each cover one side of the ablation.
GVT_RLS_NO_FUSE=1 cargo test -q --offline --test serve_concurrency

echo "== serve: offline predict vs TCP server round trip =="
bin=target/release/gvt-rls
workdir="$(mktemp -d)"
cleanup() {
  [[ -n "${server_pid:-}" ]] && kill "$server_pid" 2>/dev/null || true
  rm -rf "$workdir"
}
trap cleanup EXIT

"$bin" train --quick --max-iters 25 --save-model "$workdir/model.txt" >/dev/null

# Pair list spanning both domains (sizes parsed from the artifact).
read -r _ m q < <(grep '^domains ' "$workdir/model.txt")
for i in $(seq 0 23); do
  echo "$(( (i * 5) % m )) $(( (i * 11) % q ))"
done > "$workdir/pairs.txt"

"$bin" predict --model "$workdir/model.txt" --pairs "$workdir/pairs.txt" \
  --out "$workdir/offline.txt"

"$bin" serve --model "$workdir/model.txt" --listen 127.0.0.1:0 \
  --max-batch 64 --max-wait-us 2000 > "$workdir/server.log" 2>"$workdir/server.err" &
server_pid=$!
port=""
for _ in $(seq 1 100); do
  port="$(sed -n 's/.*listening on .*:\([0-9][0-9]*\)$/\1/p' "$workdir/server.log" | head -1)"
  [[ -n "$port" ]] && break
  sleep 0.1
done
[[ -n "$port" ]] || { echo "server did not come up"; cat "$workdir/server.err"; exit 1; }

# Burst the pair list at the server over two concurrent connections
# (odd/even split), all requests written before any response is read —
# the dispatcher coalesces what lands inside the 2 ms window.
exec 3<>"/dev/tcp/127.0.0.1/$port"
exec 4<>"/dev/tcp/127.0.0.1/$port"
i=0
while read -r d t; do
  fd=$(( 3 + i % 2 ))
  printf '{"id": %d, "pairs": [[%d, %d]]}\n' "$i" "$d" "$t" >&"$fd"
  i=$(( i + 1 ))
done < "$workdir/pairs.txt"
: > "$workdir/server_scores.txt"
for (( j = 0; j < i; j++ )); do
  fd=$(( 3 + j % 2 ))
  read -r resp <&"$fd"
  id="$(sed -n 's/.*"id": \([0-9][0-9]*\),.*/\1/p' <<< "$resp")"
  score="$(sed -n 's/.*"scores": \[\(.*\)\].*/\1/p' <<< "$resp")"
  [[ -n "$id" && -n "$score" ]] || { echo "bad response: $resp"; exit 1; }
  echo "$id $score" >> "$workdir/server_scores.txt"
done
sort -n "$workdir/server_scores.txt" | cut -d' ' -f2- > "$workdir/server_sorted.txt"

# Server responses must match the offline predictions TEXTUALLY — both
# paths render with the exact-round-trip {:.17e} format and the batcher
# is bit-identical to one-shot scoring.
diff "$workdir/offline.txt" "$workdir/server_sorted.txt"

# Mid-stream hot-reload: swap the model in from the same artifact while
# both connections stay open, then replay the whole burst — the replies
# must STILL be textually identical to the offline predictions (the
# predictor pins its factorization from the artifact alone).
printf '{"cmd": "reload"}\n' >&3
read -r ack <&3
grep -q '"ok": true' <<< "$ack" || { echo "reload not acknowledged: $ack"; exit 1; }
i=0
while read -r d t; do
  fd=$(( 3 + i % 2 ))
  printf '{"id": %d, "pairs": [[%d, %d]]}\n' "$i" "$d" "$t" >&"$fd"
  i=$(( i + 1 ))
done < "$workdir/pairs.txt"
: > "$workdir/server_scores2.txt"
for (( j = 0; j < i; j++ )); do
  fd=$(( 3 + j % 2 ))
  read -r resp <&"$fd"
  id="$(sed -n 's/.*"id": \([0-9][0-9]*\),.*/\1/p' <<< "$resp")"
  score="$(sed -n 's/.*"scores": \[\(.*\)\].*/\1/p' <<< "$resp")"
  [[ -n "$id" && -n "$score" ]] || { echo "bad post-reload response: $resp"; exit 1; }
  echo "$id $score" >> "$workdir/server_scores2.txt"
done
sort -n "$workdir/server_scores2.txt" | cut -d' ' -f2- > "$workdir/server_sorted2.txt"
diff "$workdir/offline.txt" "$workdir/server_sorted2.txt"
exec 4>&-

printf '{"cmd": "shutdown"}\n' >&3
read -r ack <&3 || true
exec 3>&-
wait "$server_pid"
server_pid=""
echo "serve round trip: OK ($i requests, 2 connections, mid-stream reload)"

echo "== eigen solver: complete-grid train + exact LOOCV + artifact round trip =="
# The direct lane end to end: train on the complete kernel-filling grid,
# select λ by exact LOOCV (zero solver iterations), save the same v2
# artifact the iterative lane writes, and score pairs through the
# untouched predict path.
"$bin" train --quick --dataset kernel-filling --solver eigen \
  --save-model "$workdir/eigen_model.txt" > "$workdir/eigen_train.out"
grep -q "solver eigen" "$workdir/eigen_train.out"
grep -q "iterations 0" "$workdir/eigen_train.out"
printf '0 0\n1 2\n3 1\n' > "$workdir/eigen_pairs.txt"
"$bin" predict --model "$workdir/eigen_model.txt" --pairs "$workdir/eigen_pairs.txt" \
  --out "$workdir/eigen_scores.txt"
[[ "$(wc -l < "$workdir/eigen_scores.txt")" -eq 3 ]]
# Incomplete data must fail in-band with the structured missing-count
# error, not a panic or a silent wrong answer.
if "$bin" train --quick --dataset metz --solver eigen 2> "$workdir/eigen_err.txt"; then
  echo "eigen on incomplete data unexpectedly succeeded"; exit 1
fi
grep -q "incomplete grid" "$workdir/eigen_err.txt"
echo "eigen lane: OK (LOOCV train, artifact round trip, in-band rejection)"

echo "== serve: injected faults answered in-band (GVT_RLS_FAULT) =="
# Dispatcher panic on the first scoring pass: request 1 gets an in-band
# internal error, request 2 is scored normally — the process must keep
# serving and exit cleanly, never abort.
GVT_RLS_FAULT=batcher_dispatch:panic:1 "$bin" serve --model "$workdir/model.txt" \
  --stdio > "$workdir/fault_panic.out" 2>/dev/null <<'EOF'
{"id": 1, "pairs": [[0, 0]]}
{"id": 2, "pairs": [[0, 0]]}
{"cmd": "shutdown"}
EOF
grep -q '"id": 1, "error": "internal error: scoring panicked' "$workdir/fault_panic.out"
grep -q '"id": 2, "scores": ' "$workdir/fault_panic.out"

# Truncated artifact read: the load must fail with a contextual error
# naming the artifact (no panic, no backtrace on the happy stderr path).
if GVT_RLS_FAULT=artifact_read:truncate:1 "$bin" predict --model "$workdir/model.txt" \
     --pairs "$workdir/pairs.txt" --out /dev/null 2> "$workdir/fault_trunc.err"; then
  echo "truncated artifact load unexpectedly succeeded"; exit 1
fi
grep -q 'model.txt' "$workdir/fault_trunc.err"
if grep -q 'panicked' "$workdir/fault_trunc.err"; then
  echo "truncated artifact load panicked instead of erroring"; exit 1
fi
echo "fault injection: OK (panic in-band, truncation contextual)"

echo "== telemetry: metrics command + Chrome trace export (GVT_RLS_TRACE) =="
# A stdio serve round trip with the trace recorder armed: the metrics
# wire command must answer with the latency registry, and the process
# must drain its span ring to valid Chrome trace-event JSON at exit.
GVT_RLS_TRACE="$workdir/trace.json" "$bin" serve --model "$workdir/model.txt" \
  --stdio > "$workdir/telemetry.out" 2>/dev/null <<'EOF'
{"id": 1, "pairs": [[0, 0]]}
{"cmd": "stats"}
{"cmd": "metrics"}
{"cmd": "shutdown"}
EOF
grep -q '"id": 1, "scores": ' "$workdir/telemetry.out"
grep -q '"latency": {"enabled": true' "$workdir/telemetry.out"
grep -q '"metrics": {"enabled": true' "$workdir/telemetry.out"
grep -q '"gvt_pass_us"' "$workdir/telemetry.out"
# The trace file must be well-formed JSON carrying trace events.
python3 -m json.tool "$workdir/trace.json" >/dev/null
grep -q '"traceEvents"' "$workdir/trace.json"
grep -q '"serve.batch"' "$workdir/trace.json"
echo "telemetry: OK (metrics in-band, trace valid JSON)"

echo "== benches execute (smoke mode: 1 warmup + 1 iter, tiny sizes) =="
# GVT_BENCH_SMOKE=1 makes every harness = false bench run a minimal
# configuration (see rust/src/bench/mod.rs) so bench code is executed —
# not just compiled — on every verify and cannot bit-rot silently. The
# list is derived from rust/benches/*.rs so new benches are picked up
# automatically.
for bench_file in rust/benches/*.rs; do
  bench="$(basename "$bench_file" .rs)"
  echo "-- $bench (smoke)"
  GVT_BENCH_SMOKE=1 cargo bench --offline --bench "$bench" >/dev/null
done

echo "verify.sh: OK"
